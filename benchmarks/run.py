# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver — one section per paper exhibit (DESIGN.md §6):

  Fig. 2/3   imbalance.run              skew + FLOP imbalance
  Fig. 13    orchestration.run          vanilla/backbone/hybrid speedups
  pipeline   orchestration.run_pipeline serial vs pipelined planning
  Fig. 12    memory_arch.run            memory vs colocated (288/576 GPU)
  Fig. 14/A  parallelism_redundancy.run simulated-backend redundancy
  Fig. 15    source_parallel.run        source-partitioning memory
  Fig. 16    fault_tolerance.run        planner/loader failure latency
  recovery   fault_tolerance.run_recovery  resume RTO vs ckpt cadence
  App. B     constructor_scaling.run    constructor fan-in at scale
  kernels    kernel_bench.run           segment-skip tile evidence
  roofline   roofline.run               dry-run roofline terms

Usage:
    python -m benchmarks.run [--only fig13,pipeline] [--json out.json]

``--only`` runs a comma-separated subset of section names; ``--json``
additionally writes every emitted row as machine-readable JSON
(name/value/units/derived — the BENCH_orchestration.json CI artifact).
"""
import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="paper-exhibit benchmark driver")
    ap.add_argument("--only", default="",
                    help="comma-separated section names to run "
                         "(default: all)")
    ap.add_argument("--json", default="",
                    help="also write emitted rows to this JSON file")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    from benchmarks import (
        common, constructor_scaling, fault_tolerance, imbalance,
        kernel_bench, memory_arch, orchestration,
        parallelism_redundancy, roofline, source_parallel,
    )
    sections = [
        ("fig2/3", imbalance.run),
        ("fig13", orchestration.run),
        ("fig13-real", orchestration.run_real_compute),
        ("pipeline", orchestration.run_pipeline),
        ("telemetry-overhead", orchestration.run_telemetry_overhead),
        ("fig12", memory_arch.run),
        ("fig14/A", parallelism_redundancy.run),
        ("fig15", source_parallel.run),
        ("fig16", fault_tolerance.run),
        ("recovery", fault_tolerance.run_recovery),
        ("appB", constructor_scaling.run),
        ("kernels", kernel_bench.run),
        ("roofline", roofline.run),
    ]
    if args.only:
        wanted = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = wanted - {name for name, _ in sections}
        if unknown:
            print(f"unknown sections: {sorted(unknown)} "
                  f"(have {[n for n, _ in sections]})", file=sys.stderr)
            sys.exit(2)
        sections = [(n, fn) for n, fn in sections if n in wanted]
    failed = []
    for name, fn in sections:
        t0 = time.time()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"section.{name},{(time.time() - t0) * 1e6:.0f},elapsed",
              flush=True)
    if args.json:
        common.write_json(args.json)
    if failed:
        print(f"FAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
