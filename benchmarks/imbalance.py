"""Fig. 2/3 analog: skewed multisource token distributions -> intra/inter
module FLOP imbalance across DP ranks and microbatches (no scheduling)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.configs import get_config
from repro.core.balance import bin_loads, imbalance
from repro.data.cost_models import backbone_cost, encoder_cost
from repro.data.sources import coyo_like_specs, navit_like_specs, \
    sample_lengths


def _draw(specs, n_per_source, seed=0):
    rng = np.random.default_rng(seed)
    metas = []
    for sp in specs:
        t, i = sample_lengths(sp, n_per_source, rng)
        for a, b in zip(t, i):
            metas.append({"text_tokens": int(a), "image_tokens": int(b),
                          "source": sp.name})
    rng.shuffle(metas)
    return metas


def run():
    cfg = get_config("paper-llama-12b")
    bb = backbone_cost(cfg)
    enc = encoder_cost(48, 1664)  # ViT-2B
    for ds_name, specs in (("coyo", coyo_like_specs(5)),
                           ("navit", navit_like_specs(40)[:40])):
        metas = _draw(specs, 256)
        # token-distribution skew (Fig. 2)
        text = np.array([m["text_tokens"] for m in metas])
        frac_small = float((text <= 64).mean())
        top = np.sort(text)[::-1]
        top_share = float(top[:max(len(top) // 60, 1)].sum() / text.sum())
        emit(f"fig2.skew.{ds_name}", 0.0,
             f"pct_text<=64tok={frac_small:.3f};"
             f"top1.6pct_token_share={top_share:.3f}")
        # microbatch FLOP imbalance under round-robin (Fig. 3)
        n_ranks, n_mb = 4, 4
        bb_costs = [bb(m) for m in metas]
        enc_costs = [enc(m) for m in metas]
        with timed(f"fig3.imbalance.{ds_name}", lambda: ""):
            assign = [i % (n_ranks * n_mb) for i in range(len(metas))]
        bl = bin_loads(bb_costs, assign, n_ranks * n_mb)
        el = bin_loads(enc_costs, assign, n_ranks * n_mb)
        emit(f"fig3.backbone_mb_ratio.{ds_name}", 0.0,
             f"max_over_min={max(bl) / max(min(bl), 1e-9):.2f};"
             f"imbalance={imbalance(bl):.3f}")
        emit(f"fig3.encoder_mb_ratio.{ds_name}", 0.0,
             f"max_over_min={max(el) / max(min(el), 1e-9):.2f};"
             f"imbalance={imbalance(el):.3f}")


if __name__ == "__main__":
    run()
