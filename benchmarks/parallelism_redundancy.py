"""Fig. 14 + Appendix A analog: parallelism-redundancy removal, evaluated
with the paper's own methodology — a dry-run 'simulated backend' that
generates dummy loading jobs and accounts memory from measured unit costs.

Unit costs (bytes) are MEASURED on this host (one SourceReader's access
state, one worker context, one sample buffer slot), then composed:

  colocated(rank_count) = ranks * [sources * reader + workers * (ctx +
                          buffer) + batch_buffer]
  overlord              = sources * reader (one each) + constructors *
                          batch_buffer, where constructors = DP groups
                          (CP/PP/TP ranks share their bucket's data).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, source_root
from repro.data.sources import materialize_group, navit_like_specs
from repro.data.storage import SourceReader


def unit_costs():
    paths = materialize_group(
        [dataclasses.replace(s, n_samples=64)
         for s in navit_like_specs(4)], source_root())
    p = next(iter(paths.values()))
    with SourceReader(p) as r:
        r.read(32)
        reader_bytes = r.access_state_bytes
    return {
        "reader": reader_bytes,
        "worker_ctx": 64 * 1024,
        "sample_slot": 4096 * 4 + 200,   # one 4k-token transformed sample
        "prefetch_slots": 64,
    }


def simulate(nodes: int, bs: int, workers: int, cp: int, pp: int,
             tp: int = 4, sources: int = 306, gpus_per_node: int = 16):
    u = unit_costs()
    world = nodes * gpus_per_node
    dp = max(world // (cp * pp * tp), 1)
    per_rank_batch = max(bs // dp, 1)
    batch_buffer = per_rank_batch * u["sample_slot"]
    worker_mem = workers * (u["worker_ctx"]
                            + u["prefetch_slots"] * u["sample_slot"])
    # colocated: EVERY rank (dp*cp*pp ranks fetch; tp>0 suppressed by
    # trainer-side broadcast in both systems for fairness)
    fetching_ranks = dp * cp * pp
    colocated = fetching_ranks * (sources * u["reader"] + worker_mem
                                  + batch_buffer)
    # overlord: per-source loaders once + per-DP-bucket constructors
    # (constructor buffers ~2 steps) + planner metadata
    overlord = sources * (u["reader"] + worker_mem) \
        + dp * (2 * batch_buffer) + (1 << 20)
    return colocated, overlord


def run():
    for cp in (1, 2, 4, 8):
        for pp in (1, 2, 4, 8):
            co, ov = simulate(nodes=512, bs=512, workers=4, cp=cp, pp=pp)
            emit(f"fig14.ratio.cp{cp}.pp{pp}", 0.0,
                 f"overlord_over_colocated={ov / co:.3f};"
                 f"saving={co / ov:.2f}x")
    # Appendix A ablations
    for bs in (512, 1024, 2048):
        co, ov = simulate(nodes=512, bs=bs, workers=4, cp=2, pp=2)
        emit(f"figA.batch_size.{bs}", 0.0,
             f"overlord_over_colocated={ov / co:.3f}")
    for workers in (4, 8, 16):
        co, ov = simulate(nodes=512, bs=512, workers=workers, cp=2, pp=2)
        emit(f"figA.workers.{workers}", 0.0,
             f"overlord_over_colocated={ov / co:.3f}")
    for nodes in (512, 1024, 4096):
        co, ov = simulate(nodes=nodes, bs=512, workers=4, cp=2, pp=2)
        emit(f"figA.cluster.{nodes}", 0.0,
             f"overlord_over_colocated={ov / co:.3f}")


if __name__ == "__main__":
    run()
