"""Shared benchmark scaffolding: timed rows in ``name,us_per_call,derived``
CSV format (one function per paper table/figure), plus a machine-readable
JSON dump (``write_json``) CI archives as a build artifact."""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import time

#: (name, value, derived, units) — value is microseconds unless the row
#: overrode ``units`` (e.g. the speedup ratios)
ROWS: list[tuple[str, float, str, str]] = []


def emit(name: str, value: float, derived: str = "",
         units: str = "us_per_call"):
    ROWS.append((name, value, derived, units))
    print(f"{name},{value:.2f},{derived}", flush=True)


@contextlib.contextmanager
def timed(name: str, derived_fn=lambda: ""):
    t0 = time.perf_counter()
    yield
    us = (time.perf_counter() - t0) * 1e6
    emit(name, us, derived_fn())


def write_json(path: str) -> None:
    """Dump every emitted row as ``[{name, value, units, derived}]`` —
    the schema the CI artifact (BENCH_orchestration.json) carries so
    perf regressions are diffable across runs."""
    data = [{"name": n, "value": v, "units": u, "derived": d}
            for n, v, d, u in ROWS]
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1, sort_keys=False)
        f.write("\n")


def source_root() -> str:
    root = os.environ.get("OVERLORD_BENCH_ROOT",
                          os.path.join(tempfile.gettempdir(),
                                       "overlord_bench_sources"))
    os.makedirs(root, exist_ok=True)
    return root
