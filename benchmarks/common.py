"""Shared benchmark scaffolding: timed rows in ``name,us_per_call,derived``
CSV format (one function per paper table/figure)."""
from __future__ import annotations

import contextlib
import os
import tempfile
import time

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


@contextlib.contextmanager
def timed(name: str, derived_fn=lambda: ""):
    t0 = time.perf_counter()
    yield
    us = (time.perf_counter() - t0) * 1e6
    emit(name, us, derived_fn())


def source_root() -> str:
    root = os.environ.get("OVERLORD_BENCH_ROOT",
                          os.path.join(tempfile.gettempdir(),
                                       "overlord_bench_sources"))
    os.makedirs(root, exist_ok=True)
    return root
