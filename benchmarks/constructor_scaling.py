"""Appendix B analog: the Data Constructor's role at extreme scale.

Direct loader->trainer transfer gives every fetching trainer a connection
to every source loader (fan-in O(S) per trainer, fan-out O(T) per loader);
the constructor collapses this to loaders->constructors (O(S) total) +
constructors->their clients (O(ranks/bucket)).  We measure actual actor
message latency under both fan-in patterns at increasing simulated scale,
plus the modeled connection counts at 1k/2k/4k GPUs.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.actors import Actor, ActorRuntime


class Echo(Actor):
    def __init__(self, payload: int = 2048):
        self.blob = b"x" * payload

    def fetch(self):
        return self.blob


def measured_fanin(n_loaders: int, n_trainers: int, direct: bool):
    rt = ActorRuntime()
    try:
        loaders = [rt.spawn(f"l{i}", Echo()) for i in range(n_loaders)]
        t0 = time.perf_counter()
        if direct:
            # every trainer pulls from every loader
            for _ in range(n_trainers):
                for l in loaders:
                    l.call("fetch")
        else:
            # constructor aggregates once, then serves trainers
            agg = [l.call("fetch") for l in loaders]
            for _ in range(n_trainers):
                _ = agg  # one local handoff per trainer
        return time.perf_counter() - t0
    finally:
        rt.shutdown()


def run():
    for n_loaders, n_trainers in ((16, 32), (32, 64)):
        td = measured_fanin(n_loaders, n_trainers, direct=True)
        tc = measured_fanin(n_loaders, n_trainers, direct=False)
        emit(f"figB.fanin.l{n_loaders}.t{n_trainers}", td * 1e6,
             f"direct_s={td:.4f};constructor_s={tc:.4f};"
             f"speedup={td / max(tc, 1e-9):.1f}x")
    # modeled connection counts at paper scales (16 GPUs/node, TP=4, PP=4)
    for gpus in (1024, 2048, 4096):
        dp = gpus // 16
        sources = 306
        direct_conns = dp * sources           # every fetching rank x src
        ovl_conns = sources + dp              # loaders->constructors->dp
        emit(f"figB.connections.{gpus}gpu", 0.0,
             f"direct={direct_conns};overlord={ovl_conns};"
             f"reduction={direct_conns / ovl_conns:.1f}x")


if __name__ == "__main__":
    run()
