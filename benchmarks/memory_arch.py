"""Fig. 12 analog: memory + fetch overhead, OVERLORD vs colocated loader.

Paper setup: Llama-12B + ViT-2B on 288/576 GPUs, navit-100 sources.  We
scale ranks to DP groups (paper: 16 GPUs/node, TP=4 x PP=4 -> DP=18/36
data consumers) and measure RESIDENT bytes of both architectures, plus
OVERLORD-auto (source auto-partitioning) vs OVERLORD-vanilla (one loader
per source).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, source_root, timed
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.core.autoscale import PartitionLimits
from repro.core.colocated import ColocatedFleet
from repro.data.cost_models import backbone_cost
from repro.data.sources import materialize_group, navit_like_specs
import dataclasses


def _paths(n_sources: int):
    specs = [dataclasses.replace(s, n_samples=128)
             for s in navit_like_specs(n_sources)]
    return materialize_group(specs, source_root())


def run(n_sources: int = 48):
    paths = _paths(n_sources)
    cfg = get_config("paper-llama-12b")
    sched = StaticSchedule({n: 1.0 for n in paths})
    for gpus, dp in (("288gpu", 18), ("576gpu", 36)):
        tree = ClientPlaceTree([("PP", 1), ("DP", dp), ("CP", 1),
                                ("TP", 1)])
        results = {}
        for mode in ("auto", "vanilla"):
            ov = Overlord(paths, tree, sched, OverlordConfig(
                seq_len=1024, rows_per_microbatch=1, n_bins=1,
                strategy="backbone_balance",
                strategy_params=dict(costfn=backbone_cost(cfg),
                                     broadcast=()),
                shadows=False, buffer_target=32,
                auto_partition=(mode == "auto"),
                limits=PartitionLimits(total_workers=dp * 2, w_actor=2),
            )).start()
            try:
                import time
                fetch = []
                for step in range(3):
                    t0 = time.perf_counter()
                    for r in range(tree.world):
                        ov.get_batch(step, r)
                    fetch.append(time.perf_counter() - t0)
                    ov.step_done(step)
                results[mode] = (ov.memory_report()["total_ex_shadows"],
                                 float(np.mean(fetch[1:])))
            finally:
                ov.shutdown()
        # colocated: every DP rank opens all sources with sized workers
        fleet = ColocatedFleet(paths, dp, workers=4, seq_len=1024, rows=1,
                               schedule=sched)
        co_mem = fleet.memory_bytes()
        fleet.close()
        for mode, (mem, fetch_s) in results.items():
            emit(f"fig12.memory.{gpus}.overlord_{mode}", fetch_s * 1e6,
                 f"mem_mb={mem / 1e6:.2f};"
                 f"reduction_vs_colocated={co_mem / max(mem, 1):.2f}x")
        emit(f"fig12.memory.{gpus}.colocated", 0.0,
             f"mem_mb={co_mem / 1e6:.2f};reduction_vs_colocated=1.00x")


if __name__ == "__main__":
    run()
