"""Fig. 15 analog: source parallelism (partitioning) effect on host memory.

navit_100 vs navit_306 across worker counts, with and without source
partitioning (SP=2): partitioned loaders each hold HALF the row-group
space, so buffers and cursors split, while the colocated worker model
replicates all access states per worker.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, source_root
from repro.data.sources import materialize_group, navit_like_specs
from repro.data.storage import SourceReader


def measured_reader_bytes(paths, shard=(0, 1), read=32):
    total = 0
    for p in paths.values():
        with SourceReader(p, shard) as r:
            r.read(min(read, max(r.num_rows, 1)))
            total += r.access_state_bytes
    return total


def run():
    import os
    from repro.data.sources import materialize_source
    root = os.path.join(source_root(), "sp_rg16")
    small = {s.name: materialize_source(
        dataclasses.replace(s, n_samples=96), root, row_group_rows=16)
        for s in navit_like_specs(100)}
    # navit_306 scaled to 150 files to keep bench runtime sane; per-file
    # unit costs are identical so the ratio is exact
    big = {s.name: materialize_source(
        dataclasses.replace(s, n_samples=96), root, row_group_rows=16)
        for s in navit_like_specs(150, seed=9)}

    for name, paths in (("navit100", small), ("navit150", big)):
        base = measured_reader_bytes(paths)
        for workers in (2, 4, 8):
            # colocated-style: every worker replicates every access state
            colocated = base * workers
            emit(f"fig15a.{name}.workers{workers}", 0.0,
                 f"colocated_access_mb={colocated / 1e6:.2f}")
        # source partitioning SP=2: two loaders each own half the row
        # groups; per-loader footprint measured (buffer splits)
        sp0 = measured_reader_bytes(paths, (0, 2))
        sp1 = measured_reader_bytes(paths, (1, 2))
        emit(f"fig15b.{name}.sp2", 0.0,
             f"per_loader_mb={max(sp0, sp1) / 1e6:.2f};"
             f"unpartitioned_mb={base / 1e6:.2f};"
             f"max_loader_reduction={base / max(max(sp0, sp1), 1):.2f}x")


if __name__ == "__main__":
    run()
