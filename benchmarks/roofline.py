"""Roofline analysis over the dry-run artifacts (deliverable g).

Terms per (arch x shape x mesh), all per-chip (the dry-run HLO is the
SPMD per-device program):

    compute_s    = rolled dot-FLOPs / 197e12      (v5e bf16 peak)
    memory_s     = rolled HBM bytes / 819e9       (HBM bw)
    collective_s = ring-model wire bytes / 50e9   (per-link ICI bw)

Methodology notes (EXPERIMENTS.md §Roofline): flops/bytes are rolled up
through `while` trip counts (XLA cost_analysis counts loop bodies once —
hlo_cost.py); memory bytes are an unfused upper bound; MODEL_FLOPS =
6*N_active*D (train) / 2*N_active*D (inference).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

BOTTLENECK_FIX = {
    "compute": "increase arithmetic intensity (larger per-chip batch, "
               "fused kernels); already near the right regime",
    "memory": "fuse elementwise chains / keep working sets in VMEM "
              "(Pallas path), cut fp32 intermediates to bf16",
    "collective": "reshard to cut cross-chip traffic: cast-before-"
                  "all-gather for FSDP, shard_map all_to_all for MoE "
                  "dispatch, overlap collectives with compute",
}


def load_cells(result_dir: str):
    cells = []
    for p in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        chips = 512 if r["mesh"] == "multi" else 256
        compute_s = r["hlo_flops_rolled"] / PEAK_FLOPS
        memory_s = r["hlo_bytes_rolled"] / HBM_BW
        coll_s = sum(r["collective_wire_bytes"].values()) / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dominant = max(terms, key=terms.get)
        mf_per_chip = r["model_flops"] / chips
        cells.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "chips": chips,
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "model_flops": r["model_flops"],
            "useful_ratio": mf_per_chip / max(r["hlo_flops_rolled"], 1.0),
            "roofline_fraction": compute_s / max(terms.values()),
            "persistent_gib": r["persistent_bytes_per_device"] / 2**30,
            "fix": BOTTLENECK_FIX[dominant],
        })
    return cells


def run(result_dir: str = "results/dryrun", emit_rows: bool = True):
    from benchmarks.common import emit
    cells = load_cells(result_dir)
    for c in cells:
        if c["mesh"] != "single":
            continue
        emit(f"roofline.{c['arch']}.{c['shape']}", 0.0,
             f"compute_s={c['compute_s']:.3e};memory_s={c['memory_s']:.3e};"
             f"collective_s={c['collective_s']:.3e};"
             f"dominant={c['dominant']};"
             f"useful={c['useful_ratio']:.2f};"
             f"roofline_frac={c['roofline_fraction']:.3f}")
    return cells


def markdown(result_dir: str = "results/dryrun") -> str:
    cells = load_cells(result_dir)
    out = ["| arch | shape | mesh | compute s | memory s | collective s |"
           " dominant | MODEL/HLO | roofline frac | GiB/chip |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.3e} | {c['memory_s']:.3e} "
            f"| {c['collective_s']:.3e} | **{c['dominant']}** "
            f"| {c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} "
            f"| {c['persistent_gib']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true")
    a = ap.parse_args()
    if a.markdown:
        print(markdown(a.dir))
    else:
        run(a.dir)
