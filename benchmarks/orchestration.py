"""Fig. 13 analog: end-to-end orchestration speedup — vanilla vs backbone
balance vs hybrid balance, across context lengths / datasets / models.

Step time under quadratic attention is set by the straggler:
    T_step ∝ max_ranks max_mb cost(mb)   (DP sync per microbatch)
so speedup(strategy) = straggler(vanilla) / straggler(strategy).  We run
the REAL planner (mix -> DGraph -> balance -> plan) over skewed draws and
report both the plan latency (us_per_call) and the modeled speedup —
plus measured wall-clock step times on a reduced model as a ground-truth
spot check (--real flag in benchmarks.run).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.configs import get_config
from repro.core.placetree import ClientPlaceTree
from repro.core.primitives import Orchestration
from repro.core.strategies import STRATEGIES
from repro.data.cost_models import backbone_cost, encoder_cost
from repro.data.sources import coyo_like_specs, navit_like_specs, \
    sample_lengths
from repro.core.mixing import StaticSchedule

MODELS = {
    "llama-12b+vit2b": ("paper-llama-12b", (48, 1664)),
    "mixtral-8x7b+vit1b": ("paper-mixtral-8x7b", (39, 1408)),
    "tmoe-25b+vit2b": ("paper-tmoe-25b", (48, 1664)),
}


def _buffer(specs, n, seed):
    rng = np.random.default_rng(seed)
    metas = []
    for sp in specs:
        t, i = sample_lengths(sp, n, rng)
        for j, (a, b) in enumerate(zip(t, i)):
            metas.append({
                "sample_id": f"{sp.name}/{j}", "source": sp.name,
                "modality": sp.modality, "text_tokens": int(a),
                "image_tokens": int(b), "transform_cost": 1.0})
    return metas


def straggler(plan, diag_key="balance:main"):
    loads = plan.diagnostics[diag_key]["bucket_loads"]
    return max(loads), float(np.mean(loads))


def run():
    tree = ClientPlaceTree([("PP", 1), ("DP", 8), ("CP", 1), ("TP", 2)])
    for model_name, (arch, vit) in MODELS.items():
        cfg = get_config(arch)
        bb = backbone_cost(cfg)
        enc = encoder_cost(*vit)
        for ds_name, specs in (("coyo", coyo_like_specs(5)),
                               ("navit", navit_like_specs(24)[:24])):
            sched = StaticSchedule({sp.name: 1.0 for sp in specs})
            for ctx in (4096, 8192, 16384):
                # samples per step sized to fill DP x ctx tokens
                metas = _buffer(specs, 192, seed=ctx)
                mean_tok = np.mean([m["text_tokens"] + m["image_tokens"]
                                    for m in metas])
                total = int(8 * ctx * 0.6 / max(mean_tok, 1))
                results = {}
                for strat in ("vanilla", "backbone_balance",
                              "hybrid_balance"):
                    ctx_o = Orchestration(metas, tree, step=0, seed=1)
                    kw = dict(schedule=sched, total=total, n_bins=2)
                    if strat == "vanilla":
                        kw["costfn"] = bb
                    elif strat == "backbone_balance":
                        kw.update(costfn=bb, broadcast=())
                    else:
                        kw.update(backbone_costfn=bb, encoder_costfn=enc,
                                  broadcast=())
                    import time
                    t0 = time.perf_counter()
                    plan = STRATEGIES[strat](ctx_o, **kw)
                    us = (time.perf_counter() - t0) * 1e6
                    mx, mean = straggler(plan)
                    results[strat] = (mx, mean, us)
                base = results["vanilla"][0]
                for strat in ("backbone_balance", "hybrid_balance"):
                    mx, mean, us = results[strat]
                    emit(f"fig13.{model_name}.{ds_name}.{ctx}.{strat}",
                         us,
                         f"speedup_vs_vanilla={base / max(mx, 1e-9):.2f};"
                         f"straggler_over_mean={mx / max(mean, 1e-9):.2f}")


def run_pipeline(steps: int = 30, warmup: int = 5,
                 compute_s: float = 0.002) -> float:
    """Pipelined vs serial planning on the LIVE stack (docs/PERFORMANCE.md).

    Two identical Overlords over 3 sources, one with the demand-driven
    serial path (plan_ahead=0, fanout_rpc=False — the pre-pipeline
    behavior) and one with plan-ahead prefetch + fan-out RPC.  The
    headline metric is the get_batch CRITICAL PATH: the client ring's
    per-fetch latency (TrainerClient.fetch_log), i.e. what one fetch
    costs when the view is not already buffered.  Serial pays the full
    planning round (collect + prepare + ingest, one mailbox round-trip
    per handle); pipelined hits the constructor fast path because the
    planner ran ahead during trainer compute.  Acceptance: >= 2x
    reduction in the steady-state median."""
    import dataclasses
    import time

    from benchmarks.common import source_root
    from repro.core import Overlord, OverlordConfig
    from repro.data.sources import materialize_group

    cfg = get_config("qwen3-8b")
    bb = backbone_cost(cfg)
    paths = materialize_group(
        [dataclasses.replace(s, n_samples=1024)
         for s in coyo_like_specs(3)], source_root())
    medians = {}
    for mode, ahead, fan in (("serial", 0, False), ("pipelined", 2, True)):
        tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1),
                                ("TP", 1)])
        ov = Overlord(paths, tree,
                      StaticSchedule({n: 1.0 for n in paths}),
                      OverlordConfig(
                          seq_len=256, rows_per_microbatch=2, n_bins=1,
                          strategy="backbone_balance",
                          strategy_params=dict(costfn=bb, broadcast=()),
                          prefetch=2, shadows=False, buffer_target=96,
                          plan_ahead=ahead, fanout_rpc=fan)).start()
        try:
            for step in range(warmup + steps):
                for r in range(ov.tree.world):
                    ov.get_batch(step, r, timeout=60)
                ov.step_done(step)
                time.sleep(compute_s)   # trainer compute: the window the
                #                         planner runs ahead in
            fetches = [dt for c in ov.clients.values()
                       for s, dt in c.fetch_log if s >= warmup]
            stalls = [dt for c in ov.clients.values()
                      for s, dt in c.stall_log if s >= warmup]
        finally:
            ov.shutdown()
        med = float(np.median(fetches))
        medians[mode] = med
        emit(f"pipeline.get_batch.{mode}", med * 1e6,
             f"p95_us={float(np.percentile(fetches, 95)) * 1e6:.0f};"
             f"stall_median_us={float(np.median(stalls)) * 1e6:.0f};"
             f"steps={steps};plan_ahead={ahead};fanout={fan}")
    speedup = medians["serial"] / max(medians["pipelined"], 1e-9)
    emit("pipeline.speedup", speedup,
         f"critical_path_reduction={speedup:.2f}x;acceptance=2.00x",
         units="x")
    return speedup


def run_telemetry_overhead(plans: int = 60, seed: int = 3):
    """Overhead of the telemetry plane on the REAL planning path: the
    same strategy run over the same buffer, instrumented the way
    ``Planner._plan_one`` is (plan-step/collect/strategy spans + the
    per-plan counters/histograms), with telemetry enabled vs disabled.
    The acceptance budget is <= 5% — the disabled path must be a few
    dict lookups, and the enabled path a handful of microseconds per
    span against a planning call that costs hundreds."""
    import time

    from repro.telemetry import Telemetry

    tree = ClientPlaceTree([("PP", 1), ("DP", 8), ("CP", 1), ("TP", 2)])
    cfg = get_config("paper-llama-12b")
    bb = backbone_cost(cfg)
    specs = coyo_like_specs(5)
    sched = StaticSchedule({sp.name: 1.0 for sp in specs})
    metas = _buffer(specs, 192, seed=seed)

    def plan_once(step, tel):
        with tel.span("planner.plan_step", step=step):
            with tel.span("planner.collect", step=step) as sp:
                sp.set_attr("buffered", len(metas))
            with tel.span("planner.strategy", step=step,
                          strategy="backbone_balance"):
                ctx = Orchestration(metas, tree, step, seed)
                plan = STRATEGIES["backbone_balance"](
                    ctx, schedule=sched, total=96, n_bins=2, costfn=bb,
                    broadcast=())
        tel.inc("planner_steps_planned_total")
        tel.observe("planner_plan_seconds", 0.001)
        return plan

    def measure(tel, rounds=3):
        """Best-of-rounds: the min mean per-plan time filters scheduler
        noise (GC pauses, noisy neighbors) that would otherwise dominate
        a single-shot measurement of a ~500us quantity."""
        for w in range(5):              # warmup
            plan_once(w, tel)
        best = float("inf")
        for _ in range(rounds):
            t0 = time.perf_counter()
            for step in range(plans):
                plan_once(step, tel)
            best = min(best, (time.perf_counter() - t0) / plans)
        return best

    times = {}
    for label, tel in (("off", Telemetry(enabled=False)),
                       ("on", Telemetry(enabled=True))):
        times[label] = measure(tel)
        emit(f"telemetry.plan_step.{label}", times[label] * 1e6, "")
    overhead = times["on"] / max(times["off"], 1e-12) - 1.0
    emit("telemetry.overhead", (times["on"] - times["off"]) * 1e6,
         f"overhead_pct={overhead * 100:.2f};budget_pct=5.00")
    return overhead


def run_real_compute(seed: int = 0):
    """Wall-clock ground truth on this host: per-DP-rank attention time
    (real matmuls, segment-local => cost ∝ sum l_i^2, which is what the
    segment-skipping Pallas kernel achieves on TPU) for vanilla vs
    balanced assignments of the same skewed sample draw."""
    import time
    import jax
    import jax.numpy as jnp
    from repro.core.balance import greedy_binpack

    rng = np.random.default_rng(seed)
    lengths = np.clip(rng.lognormal(5.0, 1.0, 96), 32, 4096).astype(int)
    # quantize to powers of two to bound jit cache size
    lengths = np.array([1 << int(np.ceil(np.log2(l))) for l in lengths])
    h, d = 8, 64

    @jax.jit
    def attn_time(q, k, v):
        logits = jnp.einsum("shd,thd->hst", q, k) / np.sqrt(d)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("hst,thd->shd", p, v)

    def rank_time(ls):
        t0 = time.perf_counter()
        for l in ls:
            q = jnp.ones((int(l), h, d), jnp.float32)
            attn_time(q, q, q).block_until_ready()
        return time.perf_counter() - t0

    n_ranks = 4
    costs = (lengths.astype(float)) ** 2
    for name, assign in (
            ("vanilla", [i % n_ranks for i in range(len(lengths))]),
            ("balanced", greedy_binpack(costs.tolist(), n_ranks))):
        per_rank = [[] for _ in range(n_ranks)]
        for l, a in zip(lengths, assign):
            per_rank[a].append(l)
        for ls in per_rank:     # warmup compile cache
            rank_time(ls)
        times = [rank_time(ls) for ls in per_rank]
        emit(f"fig13.real_attention.{name}", max(times) * 1e6,
             f"straggler_s={max(times):.4f};mean_s={np.mean(times):.4f};"
             f"imbalance={max(times) / max(np.mean(times), 1e-9):.2f}")


if __name__ == "__main__":
    run()
    run_telemetry_overhead()
    run_real_compute()
