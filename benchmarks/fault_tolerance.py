"""Fig. 16 analog: fault-tolerance latency profile.

Left: planner failures injected every 15 steps (after 5 warmup) with
prefetch buffers of 2 vs 4 — adequate prefetch fully hides the recovery.
Right: 2 loaders killed at step 35 — shadow promotion keeps delivery
uninterrupted; we report the max data-fetch stall around the event.
Chaos: a seeded FaultSchedule (crashes, io-errors, corrupt bursts,
hangs, slowdowns) soaks the full stack; reports stall percentiles,
recovery latencies, DLQ totals, and the delivery-ledger verdict.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, source_root
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group


STEP_COMPUTE_S = 0.02    # simulated trainer compute per step
RESTORE_DELAY_S = 0.05   # simulated persistent-store read latency


def _mk(paths, prefetch, shadows):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({n: 1.0 for n in paths})
    return Overlord(paths, tree, sched, OverlordConfig(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance",
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()),
        prefetch=prefetch, shadows=shadows, buffer_target=96,
        restore_delay_s=RESTORE_DELAY_S,
    )).start()


def planner_failure_profile(prefetch: int, steps: int = 40):
    paths = materialize_group(
        [dataclasses.replace(s, n_samples=512)
         for s in coyo_like_specs(3)], source_root())
    ov = _mk(paths, prefetch, shadows=False)
    stalls = []
    try:
        for step in range(steps):
            if step >= 5 and (step - 5) % 15 == 0:
                ov.inject_planner_failure()
            t0 = time.perf_counter()
            for r in range(ov.tree.world):
                ov.get_batch(step, r, timeout=30)
            stalls.append(time.perf_counter() - t0)
            ov.step_done(step)
            time.sleep(STEP_COMPUTE_S)  # trainer compute: prefetch horizon
    finally:
        ov.shutdown()
    base = float(np.median(stalls))
    spike = float(np.max(stalls))
    covered = prefetch * STEP_COMPUTE_S >= RESTORE_DELAY_S
    emit(f"fig16.planner.prefetch{prefetch}", base * 1e6,
         f"median_fetch_s={base:.4f};max_spike_s={spike:.4f};"
         f"buffer_covers_recovery={covered};"
         f"spike_over_compute={spike / STEP_COMPUTE_S:.2f}x")


def loader_failure_profile(steps: int = 50):
    paths = materialize_group(
        [dataclasses.replace(s, n_samples=512)
         for s in coyo_like_specs(3)], source_root())
    ov = _mk(paths, prefetch=2, shadows=True)
    stalls = []
    try:
        for step in range(steps):
            if step == 35:
                ov.inject_loader_failures(2)
            t0 = time.perf_counter()
            for r in range(ov.tree.world):
                ov.get_batch(step, r, timeout=30)
            stalls.append(time.perf_counter() - t0)
            ov.step_done(step)
            time.sleep(0.002)
        promos = len(ov.shadow_mgr.promotions)
        rec = max((r["recovery_s"] for r in ov.recovery_log), default=0.0)
    finally:
        ov.shutdown()
    around = float(np.max(stalls[34:40]))
    base = float(np.median(stalls[:34]))
    emit("fig16.loader.shadow", base * 1e6,
         f"promotions={promos};recovery_s={rec:.4f};"
         f"stall_at_failure_s={around:.4f};"
         f"spike_over_median={around / max(base, 1e-9):.1f}x")


def chaos_soak_profile(seed: int = 1234, steps: int = 60):
    from repro.chaos import FaultInjector, FaultSchedule

    paths = materialize_group(
        [dataclasses.replace(s, n_samples=512)
         for s in coyo_like_specs(3)], source_root())
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    ov = Overlord(paths, tree, StaticSchedule({n: 1.0 for n in paths}),
                  OverlordConfig(
                      seq_len=256, rows_per_microbatch=2, n_bins=1,
                      strategy="backbone_balance",
                      strategy_params=dict(costfn=backbone_cost(cfg),
                                           broadcast=()),
                      prefetch=2, shadows=True, ledger=True,
                      buffer_target=96,
                      restore_delay_s=RESTORE_DELAY_S)).start()
    schedule = FaultSchedule.generate(seed, steps)
    injector = FaultInjector(ov, schedule)
    stalls = []
    try:
        for step in range(steps):
            injector.on_step(step)
            t0 = time.perf_counter()
            for r in range(ov.tree.world):
                ov.get_batch(step, r, timeout=30)
            stalls.append(time.perf_counter() - t0)
            ov.step_done(step)
            time.sleep(0.002)
        time.sleep(0.3)
        ov.step_done(steps - 1)
        summary = ov.ledger.verify(strict=True)
        rec = max((r["recovery_s"] for r in ov.recovery_log), default=0.0)
        dlq_total = ov.dlq.total
    finally:
        injector.uninstall()
        ov.shutdown()
    emit(f"chaos.soak.seed{seed}", float(np.median(stalls)) * 1e6,
         f"events={len(schedule)};kinds={len(schedule.kinds())};"
         f"p99_fetch_s={float(np.percentile(stalls, 99)):.4f};"
         f"max_recovery_s={rec:.4f};quarantined={dlq_total};"
         f"ledger_ok={summary['ok']};delivered={summary['delivered']}")


def recovery_rto_profile(cadence: int, steps: int = 20):
    """RTO vs checkpoint cadence: run a job with durable manifests, kill
    the whole process, time ``resume()`` on a fresh incarnation, then
    prove continuity with the persisted delivery ledger."""
    import shutil
    import tempfile

    paths = materialize_group(
        [dataclasses.replace(s, n_samples=512)
         for s in coyo_like_specs(3)], source_root())
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    ckdir = tempfile.mkdtemp(prefix=f"bench_rto_c{cadence}_")

    def mk_job(start=True):
        ov = Overlord(paths, tree,
                      StaticSchedule({n: 1.0 for n in paths}),
                      OverlordConfig(
                          seq_len=256, rows_per_microbatch=2, n_bins=1,
                          strategy="backbone_balance",
                          strategy_params=dict(costfn=backbone_cost(cfg),
                                               broadcast=()),
                          prefetch=2, shadows=True, ledger=True,
                          buffer_target=96, checkpoint_dir=ckdir,
                          loader_ckpt_every=cadence,
                          restore_delay_s=RESTORE_DELAY_S))
        return ov.start() if start else ov

    ov = mk_job()
    ov2 = None
    try:
        for step in range(steps):
            for r in range(ov.tree.world):
                ov.get_batch(step, r, timeout=30)
            ov.step_done(step)
        ov.simulate_process_death()
        t0 = time.perf_counter()
        ov2 = mk_job(start=False).resume()
        rto = time.perf_counter() - t0
        rep = ov2.resume_report
        for step in range(rep["step"] + 1, rep["step"] + 4):
            for r in range(ov2.tree.world):
                ov2.get_batch(step, r, timeout=30)
            ov2.step_done(step)
        ok = ov2.ledger.verify(strict=False)["ok"]
    finally:
        if ov2 is not None:
            ov2.shutdown()
        else:
            ov.shutdown()
        shutil.rmtree(ckdir, ignore_errors=True)
    emit(f"recovery.rto.ckpt_every{cadence}", rto * 1e6,
         f"resume_s={rto:.4f};replayed_steps={rep['replayed_steps']};"
         f"epoch={rep['epoch']};restored={len(rep['restored'])};"
         f"ledger_ok={ok}")


def run_recovery():
    # the recovery-time tradeoff: sparser actor cuts shrink steady-state
    # checkpoint work but widen the replay window a resume must cover
    for cadence in (1, 4, 8):
        recovery_rto_profile(cadence)


def run():
    # prefetch horizon 2 x 20ms < 50ms restore => stalls; 4 x 20ms covers
    planner_failure_profile(prefetch=2)
    planner_failure_profile(prefetch=4)
    loader_failure_profile()
    chaos_soak_profile()


if __name__ == "__main__":
    run()
