"""Kernel-level evidence that packing layout changes attention cost:
the segment-aware kernel skips dead (Q, KV) tiles, so one 512-token doc
costs ~10 live causal tiles while 4x128-token docs cost only the 4
diagonal tiles.  Run in interpret mode (CPU container); tile-skip ratios
are architecture-independent and carry to TPU.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.packed_attention import packed_flash_attention


def live_tiles(seg, block=128, causal=True):
    s = len(seg)
    n = s // block
    live = 0
    for iq in range(n):
        for ik in range(n):
            if causal and ik > iq:
                continue
            qs = seg[iq * block:(iq + 1) * block]
            ks = seg[ik * block:(ik + 1) * block]
            if qs.max() >= ks.min() and ks.max() >= qs.min() \
                    and qs.max() > 0 and ks.max() > 0:
                live += 1
    return live


def run():
    b, h, s, d = 1, 2, 1024, 128
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    layouts = {
        "one_1024tok_doc": np.ones((b, s), np.int32),
        "eight_128tok_docs": np.repeat(
            np.arange(1, 9, dtype=np.int32), 128)[None].repeat(b, 0),
    }
    for name, seg in layouts.items():
        packed_flash_attention(q, q, q, seg, seg)  # warmup
        t0 = time.perf_counter()
        packed_flash_attention(q, q, q, seg, seg)
        dt = time.perf_counter() - t0
        lt = live_tiles(seg[0])
        total_tiles = (s // 128) * (s // 128 + 1) // 2
        emit(f"kernel.segment_skip.{name}", dt * 1e6,
             f"live_tiles={lt}/{total_tiles};cost_model_sum_l2="
             f"{sum(int((seg[0] == i).sum()) ** 2 for i in range(1, seg.max() + 1))}")


if __name__ == "__main__":
    run()
