"""Units for the hardening primitives (repro.core.resilience), the
fault-path failure accounting (CheckpointStore / ShadowManager stats),
the DeliveryLedger invariants, and the ActorRuntime supervision edge
cases (hung stop(), exactly-once death callbacks, death during an
in-flight call)."""
import threading
import time

import pytest

from repro.chaos.ledger import DeliveryLedger, LedgerViolation
from repro.core.actors import Actor, ActorDied, ActorRuntime
from repro.core.fault import CheckpointStore, ShadowManager
from repro.core.resilience import (
    CircuitBreaker, DeadLetterQueue, RetryPolicy, TransientIOError,
    validate_positive_policy,
)


# --------------------------------------------------------- RetryPolicy
class TestRetryPolicy:
    def test_retries_transient_then_succeeds(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise TransientIOError("blip")
            return "ok"

        pol = RetryPolicy(max_attempts=3, base_delay_s=0.001,
                          max_delay_s=0.002)
        assert pol.run(flaky) == "ok"
        assert len(calls) == 3

    def test_exhausts_attempts_and_raises(self):
        calls = []

        def always():
            calls.append(1)
            raise TimeoutError("never")

        pol = RetryPolicy(max_attempts=4, base_delay_s=0.001,
                          max_delay_s=0.002)
        with pytest.raises(TimeoutError):
            pol.run(always)
        assert len(calls) == 4

    def test_non_retryable_raises_immediately(self):
        calls = []

        def bad():
            calls.append(1)
            raise ValueError("logic bug")

        pol = RetryPolicy(max_attempts=5, base_delay_s=0.001)
        with pytest.raises(ValueError):
            pol.run(bad)
        assert len(calls) == 1

    def test_classification(self):
        pol = RetryPolicy()
        assert pol.is_retryable(TimeoutError())
        assert pol.is_retryable(TransientIOError())
        assert not pol.is_retryable(ValueError())
        custom = RetryPolicy(retryable=(KeyError,))
        assert custom.is_retryable(KeyError())
        assert not custom.is_retryable(TimeoutError())

    def test_deterministic_jitter(self):
        a = RetryPolicy(seed=42)
        b = RetryPolicy(seed=42)
        assert [a.delay(i) for i in range(5)] \
            == [b.delay(i) for i in range(5)]
        # delays grow and stay bounded
        no_jitter = RetryPolicy(jitter=0.0, base_delay_s=0.01,
                                max_delay_s=0.05, multiplier=2.0)
        assert no_jitter.delay(0) == pytest.approx(0.01)
        assert no_jitter.delay(1) == pytest.approx(0.02)
        assert no_jitter.delay(10) == pytest.approx(0.05)

    def test_on_retry_hook(self):
        seen = []
        pol = RetryPolicy(max_attempts=3, base_delay_s=0.001)

        def flaky():
            if len(seen) < 2:
                raise TransientIOError("x")
            return 1

        assert pol.run(flaky, on_retry=lambda a, e: seen.append(a)) == 1
        assert seen == [0, 1]

    def test_validate_positive_policy(self):
        assert validate_positive_policy(RetryPolicy())
        assert not validate_positive_policy(RetryPolicy(max_attempts=0))
        assert not validate_positive_policy(
            RetryPolicy(base_delay_s=1.0, max_delay_s=0.1))
        assert not validate_positive_policy(RetryPolicy(multiplier=0.5))
        assert not validate_positive_policy(RetryPolicy(jitter=2.0))


# ------------------------------------------------------ CircuitBreaker
class TestCircuitBreaker:
    def test_opens_after_threshold(self):
        clk = [0.0]
        br = CircuitBreaker(failure_threshold=3, cooldown_s=1.0,
                            clock=lambda: clk[0])
        assert br.state == "closed"
        for _ in range(2):
            br.record_failure()
        assert br.state == "closed"
        br.record_failure()
        assert br.state == "open"
        assert not br.allow()

    def test_half_open_probe_then_close(self):
        clk = [0.0]
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0,
                            clock=lambda: clk[0])
        br.record_failure()
        assert not br.allow()
        clk[0] = 1.5
        assert br.allow()             # the single half-open probe
        assert br.state == "half_open"
        assert not br.allow()         # only one probe in flight
        br.record_success()
        assert br.state == "closed"
        assert br.allow()

    def test_half_open_failure_reopens(self):
        clk = [0.0]
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0,
                            clock=lambda: clk[0])
        br.record_failure()
        clk[0] = 1.5
        assert br.allow()
        br.record_failure()
        assert br.state == "open"
        assert not br.allow()
        assert br.stats()["opens"] == 2

    def test_success_resets_consecutive(self):
        br = CircuitBreaker(failure_threshold=2)
        br.record_failure()
        br.record_success()
        br.record_failure()
        assert br.state == "closed"


# ----------------------------------------------------- DeadLetterQueue
class TestDeadLetterQueue:
    def test_attribution_and_counts(self):
        dlq = DeadLetterQueue(capacity=10)
        dlq.put("s1", "web", "bad tokens")
        dlq.put("s2", "web", "bad id")
        dlq.put("s3", "code", "bad cost")
        assert dlq.total == 3
        assert dlq.counts_by_source() == {"web": 2, "code": 1}
        assert dlq.sample_ids() == {"s1", "s2", "s3"}
        assert all(it["reason"] for it in dlq.items())

    def test_bounded_but_counts_full_history(self):
        dlq = DeadLetterQueue(capacity=2)
        for i in range(5):
            dlq.put(f"s{i}", "web", "r")
        assert len(dlq) == 2
        assert dlq.total == 5
        assert dlq.counts_by_source() == {"web": 5}


# ------------------------------------------------------ DeliveryLedger
class TestDeliveryLedger:
    def test_clean_run_verifies(self):
        led = DeliveryLedger()
        led.record_planned(0, "a", "web", 0)
        led.record_planned(0, "b", "web", 0)
        led.record_delivered(0, 0, 0, ["a", "b"])
        led.record_delivered(0, 1, 0, ["a", "b"])
        s = led.verify()
        assert s["ok"] and s["delivered"] == 2

    def test_lost_sample_flagged(self):
        led = DeliveryLedger()
        led.record_planned(0, "a", "web", 0)
        led.record_planned(0, "gone", "web", 0)
        led.record_delivered(0, 0, 0, ["a"])
        with pytest.raises(LedgerViolation, match="lost"):
            led.verify()
        assert [x[0] for x in led.verify(strict=False)["lost"]] == ["gone"]

    def test_duplicate_delivery_flagged(self):
        led = DeliveryLedger()
        led.record_planned(0, "a", "web", 0)
        led.record_delivered(0, 0, 0, ["a"])
        led.record_delivered(1, 0, 0, ["a"])
        with pytest.raises(LedgerViolation, match="duplicated"):
            led.verify()

    def test_drop_and_quarantine_are_accounted(self):
        led = DeliveryLedger()
        led.record_planned(0, "a", "web", 0)
        led.record_planned(0, "b", "web", 0)
        led.record_delivered(0, 0, 0, ["a"])
        led.record_dropped(0, "b", "packing_overflow")
        assert led.verify()["ok"]
        led2 = DeliveryLedger()
        led2.record_planned(0, "q", "web", 0)
        led2.record_quarantined("q", "web", "corrupt")
        led2.record_delivered(0, 0, 0, [])
        assert led2.verify()["ok"]

    def test_rank_skew_flagged(self):
        led = DeliveryLedger()
        led.record_delivered(0, 0, 0, ["a"])
        led.record_delivered(0, 1, 0, ["b"])
        s = led.verify(strict=False)
        assert not s["ok"] and s["rank_skew"]

    def test_quarantine_leak_flagged(self):
        led = DeliveryLedger()
        led.record_quarantined("x", "web")
        led.record_delivered(0, 0, 0, ["x"])
        with pytest.raises(LedgerViolation, match="quarantined"):
            led.verify()

    def test_undelivered_future_steps_not_lost(self):
        led = DeliveryLedger()
        led.record_delivered(0, 0, 0, ["a"])
        led.record_planned(0, "a", "web", 0)
        led.record_planned(5, "later", "web", 0)   # prefetch-planned
        assert led.verify()["ok"]                  # horizon = step 0


# ------------------------------------ fault-path failure accounting
class _StatefulActor(Actor):
    def __init__(self, fail_ckpt=False):
        self.fail_ckpt = fail_ckpt
        self.x = 0

    def bump(self):
        self.x += 1
        return self.x

    def checkpoint_state(self):
        if self.fail_ckpt:
            raise RuntimeError("broken state")
        return {"x": self.x}

    def restore_state(self, state):
        self.x = state["x"]


def test_checkpoint_store_counts_failures():
    rt = ActorRuntime(heartbeat_interval=0.01)
    store = CheckpointStore(planner_every=1)
    good = rt.spawn("good", _StatefulActor())
    bad = rt.spawn("bad", _StatefulActor(fail_ckpt=True))
    try:
        assert store.maybe_save("planner", "good", 0, good)
        assert not store.maybe_save("planner", "bad", 0, bad)
        assert not store.maybe_save("planner", "bad", 1, bad)
        st = store.stats()
        assert st["saves"] == {"good": 1}
        assert st["save_failures"] == {"bad": 2}
        assert "RuntimeError" in st["last_failure"]["bad"]
        assert st["checkpointed_steps"] == {"good": 0}
    finally:
        rt.shutdown()


def test_shadow_manager_tracks_staleness_and_failures():
    rt = ActorRuntime(heartbeat_interval=0.01)
    mgr = ShadowManager(rt, lambda name: _StatefulActor())
    active = rt.spawn("loader:a", _StatefulActor())
    broken = rt.spawn("loader:b", _StatefulActor(fail_ckpt=True))
    try:
        mgr.ensure_shadow("loader:a")
        mgr.ensure_shadow("loader:b")
        assert mgr.sync("loader:a", active, step=3)
        assert not mgr.sync("loader:b", broken, step=3)
        assert mgr.synced_step("loader:a") == 3
        assert mgr.synced_step("loader:b") == -1
        st = mgr.stats()
        assert st["sync_failures"] == {"loader:b": 1}
        assert st["staleness_steps"]["loader:a"] == 0
        assert st["staleness_steps"]["loader:b"] == 4   # never synced
        # promotion records the synced step and resets it for the next
        # shadow generation
        promoted = mgr.promote("loader:a")
        assert promoted is not None
        assert mgr.promotions[-1]["synced_step"] == 3
        assert mgr.synced_step("loader:a") == -1
    finally:
        rt.shutdown()


# --------------------------------------------- supervision edge cases
class _SlowActor(Actor):
    def __init__(self, block_s=10.0):
        self.block_s = block_s

    def sleepy(self):
        time.sleep(self.block_s)
        return "done"

    def quick(self):
        return "ok"


def test_stop_detects_hung_actor_and_reports():
    rt = ActorRuntime(heartbeat_interval=0.01)
    deaths = []
    rt.on_failure(lambda name, h: deaths.append(name))
    h = rt.spawn("wedged", _SlowActor(block_s=30.0))
    try:
        assert h.call("quick") == "ok"
        fut = h.call_async("sleepy")      # wedge the mailbox thread
        time.sleep(0.05)
        h.stop(timeout=0.1)               # join times out
        assert h.hung
        assert not h.alive
        with pytest.raises(ActorDied):
            fut.result(timeout=1)
        # the monitor treats the hang as a death: callback fires
        deadline = time.time() + 2
        while "wedged" not in deaths and time.time() < deadline:
            time.sleep(0.01)
        assert deaths == ["wedged"]
    finally:
        rt.shutdown()


def test_graceful_stop_is_not_reported_as_death():
    rt = ActorRuntime(heartbeat_interval=0.01)
    deaths = []
    rt.on_failure(lambda name, h: deaths.append(name))
    h = rt.spawn("calm", _SlowActor())
    try:
        assert h.call("quick") == "ok"
        h.stop(timeout=2.0)
        assert not h.hung and not h.alive
        time.sleep(0.1)
        assert deaths == []
    finally:
        rt.shutdown()


def test_failure_callback_fires_exactly_once_per_death():
    rt = ActorRuntime(heartbeat_interval=0.01)
    deaths = []
    rt.on_failure(lambda name, h: deaths.append(name))
    h = rt.spawn("victim", _SlowActor())
    try:
        h.kill()
        time.sleep(0.2)   # several heartbeat periods
        assert deaths == ["victim"]
        # respawn under the same name, kill again: exactly one more
        h2 = rt.spawn("victim", _SlowActor())
        assert h2.call("quick") == "ok"
        h2.kill()
        time.sleep(0.2)
        assert deaths == ["victim", "victim"]
    finally:
        rt.shutdown()


def test_reassign_then_kill_reports_under_new_name():
    """Shadow promotion remaps a live actor to the primary name; its
    death afterwards must be reported under the NEW name."""
    rt = ActorRuntime(heartbeat_interval=0.01)
    deaths = []
    rt.on_failure(lambda name, h: deaths.append(name))
    shadow = rt.spawn("loader:x::shadow", _SlowActor())
    try:
        promoted = rt.reassign("loader:x::shadow", "loader:x")
        assert promoted.call("quick") == "ok"
        promoted.kill()
        deadline = time.time() + 2
        while not deaths and time.time() < deadline:
            time.sleep(0.01)
        assert deaths == ["loader:x"]
    finally:
        rt.shutdown()


def test_kill_during_inflight_call_raises_actor_died_promptly():
    rt = ActorRuntime(heartbeat_interval=0.01)
    h = rt.spawn("busy", _SlowActor(block_s=30.0))
    try:
        fut = h.call_async("sleepy")
        time.sleep(0.05)
        t0 = time.time()
        h.kill()
        with pytest.raises(ActorDied):
            fut.result(timeout=5)
        assert time.time() - t0 < 1.0   # failed fast, not via timeout
        # queued (not yet in-flight) mail also fails, and new calls
        # are rejected immediately
        with pytest.raises(ActorDied):
            h.call("quick", timeout=5)
    finally:
        rt.shutdown()


def test_call_with_retry_rides_through_respawn():
    rt = ActorRuntime(heartbeat_interval=0.01)
    h = rt.spawn("phoenix", _SlowActor())
    try:
        h.kill()
        respawned = threading.Timer(
            0.1, lambda: rt.spawn("phoenix", _SlowActor()))
        respawned.start()
        pol = RetryPolicy(max_attempts=8, base_delay_s=0.05,
                          max_delay_s=0.1)
        assert rt.call_with_retry("phoenix", "quick", retry=pol) == "ok"
    finally:
        rt.shutdown()
