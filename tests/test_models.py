"""Per-arch smoke tests (reduced configs): forward + one train step on CPU,
shape/NaN assertions, and decode-consistency checks."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import all_reduced_configs, make_lm_batch
from repro.models.model_zoo import build_model
from repro.train.train_step import init_train_state, make_train_step

CONFIGS = all_reduced_configs()


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.name for c in CONFIGS])
def test_forward_shapes_and_finite(cfg):
    model = build_model(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    b, s = 2, 64
    batch = make_lm_batch(cfg, b, s)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.name for c in CONFIGS])
def test_one_train_step_no_nans(cfg):
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(1))
    step = jax.jit(make_train_step(model))
    batch = make_lm_batch(cfg, 2, 64)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state.opt.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.name for c in CONFIGS])
def test_prefill_decode_consistency(cfg):
    """decode_step at position s (on a prefix cache) must reproduce the
    training forward's logits for the last token (single segment)."""
    model = build_model(cfg)
    params = model.init(jax.random.key(2), jnp.float32)
    b, s = 2, 32
    batch = make_lm_batch(cfg, b, s, n_segments=1, trailing_pad=0)
    logits_f, _ = jax.jit(model.forward)(params, batch)
    logits_p, _cache = jax.jit(model.prefill)(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(logits_f[:, -1], np.float32), atol=2e-3, rtol=2e-3)


def test_transformer_decode_matches_forward():
    """Token-by-token decode equals the packed forward (qwen3 reduced)."""
    from repro.configs.qwen3_8b import reduced
    cfg = reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(3), jnp.float32)
    b, s = 1, 16
    batch = make_lm_batch(cfg, b, s, n_segments=1, trailing_pad=0)
    logits_f, _ = jax.jit(model.forward)(params, batch)
    cache = model.init_cache(b, s, jnp.float32)
    decode = jax.jit(model.decode_step)
    for t in range(s):
        logits_d, cache = decode(params, cache,
                                 batch["tokens"][:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_d[:, 0], np.float32),
                               np.asarray(logits_f[:, -1], np.float32),
                               atol=2e-3, rtol=2e-3)


def test_packed_segments_are_independent():
    """Packing isolation: a segment's logits must not depend on the other
    segments packed into the same row (attention-family archs)."""
    from repro.configs.yi_9b import reduced
    cfg = reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(4), jnp.float32)
    rng = np.random.default_rng(0)
    s = 64
    a = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
    bpart = rng.integers(1, cfg.vocab_size, 30).astype(np.int32)
    c = rng.integers(1, cfg.vocab_size, 30).astype(np.int32)

    def packed(second):
        tokens = np.zeros((1, s), np.int32)
        seg = np.zeros((1, s), np.int32)
        pos = np.zeros((1, s), np.int32)
        tokens[0, :24] = a
        seg[0, :24] = 1
        pos[0, :24] = np.arange(24)
        tokens[0, 24:54] = second
        seg[0, 24:54] = 2
        pos[0, 24:54] = np.arange(30)
        return dict(tokens=tokens, segment_ids=seg, positions=pos)

    f = jax.jit(model.forward)
    l1, _ = f(params, packed(bpart))
    l2, _ = f(params, packed(c))
    np.testing.assert_allclose(np.asarray(l1[0, :24], np.float32),
                               np.asarray(l2[0, :24], np.float32),
                               atol=1e-4, rtol=1e-4)


def test_zamba2_shared_block_is_shared():
    from repro.configs.zamba2_7b import reduced
    cfg = reduced()
    model = build_model(cfg)
    n_blocks = cfg.num_layers // cfg.attn_every
    # exactly ONE copy of shared-attn params regardless of applications
    sp = model.defs["shared_attn"]["attn"]["wq"]
    assert sp.shape[0] == cfg.d_model
    assert n_blocks > 1


def test_vlm_image_fusion_changes_logits():
    from repro.configs.pixtral_12b import reduced
    cfg = reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(5), jnp.float32)
    batch = make_lm_batch(cfg, 1, 32, n_segments=1, trailing_pad=0)
    l1, _ = jax.jit(model.forward)(params, batch)
    batch2 = dict(batch)
    batch2["image_embeds"] = batch["image_embeds"] + 1.0
    l2, _ = jax.jit(model.forward)(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4
