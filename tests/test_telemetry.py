"""Unified telemetry plane: metric/tracer units, export surfaces, the
step_done metrics round-trip, and a seeded chaos soak asserting the
acceptance invariants (delivered counts reconcile with the DeliveryLedger,
every injected fault appears as a fault-stamped span, exports parse)."""
import json
import math
import os
import threading

import pytest

from repro.chaos import FaultInjector, FaultSchedule
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group
from repro.telemetry import (
    NULL_TELEMETRY, Counter, Histogram, MetricsRegistry, Telemetry,
    Tracer, canonical_spans, chrome_trace, parse_prometheus,
    render_prometheus,
)

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))


# =====================================================================
# metric primitives
# =====================================================================

def test_counter_monotone_and_merge():
    c = Counter()
    c.inc()
    c.inc(4.0)
    assert c.value == 5.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    assert Counter(2.0).merge(Counter(3.0)).value == 5.0


def test_histogram_quantiles_monotone_and_exact_moments():
    h = Histogram(capacity=64, seed=1)
    vals = [float(v) for v in range(200, 0, -1)]
    for v in vals:
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 200
    assert snap["sum"] == sum(vals)
    assert snap["min"] == 1.0 and snap["max"] == 200.0
    qs = h.quantiles([0.0, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0])
    assert qs == sorted(qs)          # monotone in q
    assert math.isnan(Histogram().quantile(0.5))


def test_registry_series_identity_and_readers():
    reg = MetricsRegistry(seed=3)
    reg.inc("reads", 2.0, source="a")
    reg.inc("reads", 3.0, source="a")
    reg.inc("reads", 7.0, source="b")
    # stringified labels: rank=0 and rank="0" are the same series
    reg.set_gauge("depth", 4.0, rank=0)
    assert reg.gauge_value("depth", rank="0") == 4.0
    assert reg.counter_value("reads", source="a") == 5.0
    assert reg.counter_total("reads") == 12.0
    assert reg.counter_value("reads", source="zzz") == 0.0
    snap = reg.snapshot()
    assert snap["counters"]['reads{source="a"}'] == 5.0


def test_registry_merge_counters_add_gauges_latest_win():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("n", 2.0)
    b.inc("n", 3.0)
    a.set_gauge("g", 1.0)
    b.set_gauge("g", 9.0)
    a.observe("h", 1.0)
    b.observe("h", 3.0)
    m = a.merge(b)
    assert m.counter_value("n") == 5.0
    assert m.gauge_value("g") == 9.0
    assert m.histogram("h").count == 2


# =====================================================================
# tracer
# =====================================================================

def test_spans_nest_per_thread_and_record_errors():
    tr = Tracer()
    with tr.span("outer", step=1) as outer:
        with tr.span("inner", source="s") as inner:
            assert tr.current() is inner
        assert tr.current() is outer
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    spans = {s.name: s for s in tr.finished()}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["boom"].attrs["error"] == "RuntimeError"
    assert tr.find("inner", source="s")


def test_span_stacks_are_thread_local():
    tr = Tracer()
    seen = {}

    def worker():
        with tr.span("child.thread"):
            seen["parent"] = tr.finished(), tr.current().parent_id

    with tr.span("main.outer"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    # the side thread's span must NOT nest under the main thread's stack
    assert seen["parent"][1] is None


def test_tracer_bound_evicts_and_counts():
    tr = Tracer(max_spans=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 4 and tr.dropped == 6


# =====================================================================
# exports
# =====================================================================

def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.inc("reads_total", 5.0, source="a")
    reg.set_gauge("depth", 2.0)
    for v in (1.0, 2.0, 3.0):
        reg.observe("lat", v)
    text = render_prometheus(reg, namespace="repro")
    parsed = parse_prometheus(text)
    assert parsed['repro_reads_total{source="a"}'] == 5.0
    assert parsed["repro_depth"] == 2.0
    assert parsed["repro_lat_count"] == 3.0
    assert parsed['repro_lat{quantile="0.5"}'] == 2.0


def test_chrome_trace_is_json_and_canonical_tree_nests():
    tr = Tracer()
    with tr.span("planner.plan_step", step=0):
        with tr.span("planner.collect", step=0):
            pass
    ct = chrome_trace(tr)
    json.dumps(ct)   # serializable
    names = {e.get("name") for e in ct["traceEvents"]}
    assert {"planner.plan_step", "planner.collect"} <= names
    forest = canonical_spans(tr.finished())
    assert forest[0]["name"] == "planner.plan_step"
    assert forest[0]["children"][0]["name"] == "planner.collect"
    # no timestamps or ids survive canonicalization
    assert "start" not in forest[0] and "span_id" not in forest[0]


# =====================================================================
# disabled plane
# =====================================================================

def test_disabled_telemetry_is_inert():
    tel = Telemetry(enabled=False)
    with tel.span("x", a=1) as sp:
        sp.set_attr("k", "v")
        sp.stamp_fault("crash")
    tel.inc("c")
    tel.set_gauge("g", 1.0)
    tel.observe("h", 1.0)
    assert len(tel.tracer) == 0
    assert tel.registry.counter_total("c") == 0.0
    assert NULL_TELEMETRY.enabled is False


# =====================================================================
# live data plane
# =====================================================================

N_SOURCES = 3
SOAK_STEPS = 30


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("telemetry_sources")
    return materialize_group(coyo_like_specs(N_SOURCES), str(root))


def mk(source_paths, **kw):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0
                            for i in range(N_SOURCES)})
    defaults = dict(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance", shadows=True, ledger=True,
        loader_ckpt_every=4,
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()))
    defaults.update(kw)
    return Overlord(source_paths, tree, sched,
                    OverlordConfig(**defaults)).start()


def test_step_done_metrics_round_trip(source_paths):
    ov = mk(source_paths, shadows=False, ledger=False)
    try:
        for step in range(3):
            for r in range(ov.tree.world):
                ov.get_batch(step, r, timeout=30)
            ov.step_done(step, {"loss": 2.5 - step, "grad_norm": 1.25,
                                "tag": "not-a-number", "flag": True})
        reg = ov.telemetry.registry
        assert reg.gauge_value("train_metric", metric="loss") == 0.5
        assert reg.gauge_value("train_metric", metric="grad_norm") == 1.25
        # non-numeric values are skipped, not crashed on
        assert math.isnan(reg.gauge_value("train_metric", metric="tag"))
        assert math.isnan(reg.gauge_value("train_metric", metric="flag"))
        assert reg.counter_value("train_steps_total") == 3.0
        assert reg.gauge_value("train_step") == 2.0
    finally:
        ov.shutdown()


def test_chaos_soak_telemetry_invariants(source_paths):
    """Acceptance: a seeded soak where (a) the telemetry delivered-sample
    count equals the DeliveryLedger's, (b) every injected fault appears
    as a fault-stamped span, (c) both export formats parse non-empty."""
    schedule = FaultSchedule.generate(CHAOS_SEED, SOAK_STEPS, rate=0.2)
    ov = mk(source_paths)
    injector = FaultInjector(ov, schedule)
    try:
        for step in range(SOAK_STEPS):
            injector.on_step(step)
            for r in range(ov.tree.world):
                v = ov.get_batch(step, r, timeout=30)
                assert v["role"] in ("data", "metadata", "none")
            ov.step_done(step)
        rep = ov.telemetry_report()

        # (a) delivered-sample reconciliation with the ledger
        ledger = ov.ledger.verify(strict=False)
        assert rep["delivery"]["delivered_samples"] == ledger["delivered"]

        # (b) every timeline entry has a matching fault-stamped span
        tracer = ov.telemetry.tracer
        for (step, kind, target, _params) in injector.timeline():
            stamped = tracer.find("chaos.inject", fault=kind, step=step,
                                  target=str(target))
            assert stamped, f"no fault-stamped span for {kind}@{step}"

        # (c) exports parse and are non-empty
        prom = parse_prometheus(ov.prometheus_dump())
        assert len(prom) > 10
        assert prom.get("repro_chaos_faults_injected_total{"
                        'kind="' + injector.timeline()[0][1] + '"}')
        ct = ov.chrome_trace()
        json.dumps(ct)
        assert len(ct["traceEvents"]) > 10

        # unified report shape
        assert rep["enabled"] is True
        assert set(rep) == {"enabled", "metrics", "memory", "resilience",
                            "diagnostics", "delivery", "spans"}
        assert rep["delivery"]["per_rank_tokens"]
        assert rep["delivery"]["token_imbalance"] >= 1.0
        assert rep["spans"]["finished"] > 0
    finally:
        injector.uninstall()
        ov.shutdown()


def test_write_chrome_trace_file(tmp_path, source_paths):
    ov = mk(source_paths, shadows=False, ledger=False)
    try:
        for r in range(ov.tree.world):
            ov.get_batch(0, r, timeout=30)
        path = tmp_path / "trace.json"
        ov.write_chrome_trace(path)
        data = json.loads(path.read_text())
        assert data["traceEvents"]
    finally:
        ov.shutdown()
