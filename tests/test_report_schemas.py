"""Direct unit tests for the Overlord report surfaces' field schemas.

``memory_report()`` / ``resilience_report()`` were previously only
exercised incidentally; the unified ``telemetry_report()`` embeds both,
so their shapes are now load-bearing contracts."""
import pytest

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group

N_SOURCES = 2


@pytest.fixture(scope="module")
def overlord(tmp_path_factory):
    root = tmp_path_factory.mktemp("schema_sources")
    paths = materialize_group(coyo_like_specs(N_SOURCES), str(root))
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0
                            for i in range(N_SOURCES)})
    cfg = OverlordConfig(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance", shadows=True, ledger=True,
        checkpoint_dir=str(tmp_path_factory.mktemp("schema_ckpt")),
        strategy_params=dict(costfn=backbone_cost(get_config("qwen3-8b")),
                             broadcast=()))
    ov = Overlord(paths, tree, sched, cfg).start()
    for step in range(3):
        for r in range(ov.tree.world):
            ov.get_batch(step, r, timeout=30)
        ov.step_done(step)
    yield ov
    ov.shutdown()


def test_memory_report_schema(overlord):
    rep = overlord.memory_report()
    assert set(rep) == {"loaders", "shadows", "constructors", "planner",
                        "total_ex_shadows"}
    for v in rep.values():
        assert isinstance(v, int) and v >= 0
    assert rep["total_ex_shadows"] == (rep["loaders"]
                                       + rep["constructors"]
                                       + rep["planner"])


def test_resilience_report_schema(overlord):
    rep = overlord.resilience_report()
    assert set(rep) == {"checkpoints", "shadows", "dlq", "loaders",
                        "recoveries"}
    assert set(rep["dlq"]) == {"total", "held", "by_source"}
    assert set(rep["checkpoints"]) == {"saves", "save_failures",
                                       "load_failures", "last_failure",
                                       "checkpointed_steps", "epoch",
                                       "fence_token", "fenced_writes",
                                       "manifests_committed",
                                       "manifest_fallbacks"}
    assert set(rep["shadows"]) == {"sync_failures", "synced_steps",
                                   "staleness_steps", "promotions"}
    assert rep["loaders"], "at least one live primary loader expected"
    for health in rep["loaders"].values():
        assert set(health) == {"source", "breaker", "read_failures",
                               "quarantined", "buffer_depth"}
    assert isinstance(rep["recoveries"], int)


def test_dlq_stats_matches_legacy_fields(overlord):
    dlq = overlord.dlq
    stats = dlq.stats()
    assert stats["total"] == dlq.total
    assert stats["held"] == len(dlq)
    assert stats["by_source"] == dlq.counts_by_source()


def test_telemetry_report_embeds_both(overlord):
    rep = overlord.telemetry_report()
    assert rep["memory"] == overlord.memory_report()
    assert set(rep["resilience"]) == set(overlord.resilience_report())
    assert set(rep["delivery"]) == {"delivered_samples",
                                    "per_rank_tokens", "token_imbalance"}
    assert set(rep["spans"]) == {"finished", "dropped"}
