"""Per-kernel shape/dtype sweeps: pallas_call (interpret) vs ref.py oracle."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.packed_attention import packed_flash_attention
from repro.kernels.wkv6 import wkv6_forward

rng = np.random.default_rng(7)


def _segs(b, s):
    out = np.zeros((b, s), np.int32)
    for i in range(b):
        pos, sid = 0, 1
        while pos < s:
            ln = int(rng.integers(4, max(s // 2, 5)))
            out[i, pos:pos + ln] = sid
            pos += ln
            sid += 1
        if rng.random() < 0.5:
            out[i, -int(rng.integers(1, s // 4 + 1)):] = 0
    return out


TOL = {np.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,h,kh,s,d", [
    (2, 4, 2, 256, 64),
    (1, 8, 1, 128, 32),    # MQA
    (2, 2, 2, 384, 128),   # MHA, non-pow2 block count
    (1, 6, 3, 128, 80),    # odd head_dim (qwen3-32b style)
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_packed_attention_sweep(b, h, kh, s, d, dtype, causal):
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), dtype)
    seg = _segs(b, s)
    out = packed_flash_attention(q, k, v, seg, seg, causal=causal,
                                 block_q=128, block_k=128)
    exp = ref.packed_attention_ref(q, k, v, seg, seg, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_packed_attention_blocks_cross_segment_leakage():
    """Zeroing one segment's V must not change another segment's output."""
    b, h, s, d = 1, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    v = np.asarray(rng.normal(size=(b, h, s, d)), np.float32)
    seg = np.ones((b, s), np.int32)
    seg[:, 64:] = 2
    out1 = packed_flash_attention(q, k, jnp.asarray(v), seg, seg)
    v2 = v.copy()
    v2[:, :, 64:, :] = 0.0  # nuke segment 2's values
    out2 = packed_flash_attention(q, k, jnp.asarray(v2), seg, seg)
    np.testing.assert_allclose(np.asarray(out1)[:, :, :64],
                               np.asarray(out2)[:, :, :64], atol=1e-6)


@pytest.mark.parametrize("b,h,kh,S,d,blk", [
    (2, 8, 2, 512, 64, 256),
    (4, 4, 4, 256, 32, 64),
    (1, 16, 2, 1024, 128, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, h, kh, S, d, blk, dtype):
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    kc = jnp.asarray(rng.normal(size=(b, kh, S, d)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, kh, S, d)), dtype)
    clen = rng.integers(1, S, size=(b,)).astype(np.int32)
    out = flash_decode(q, kc, vc, clen, block_k=blk)
    exp = ref.flash_decode_ref(q, kc, vc, clen)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,h,s,dk,chunk", [
    (2, 3, 128, 32, 32),
    (1, 2, 192, 64, 64),
    (2, 2, 64, 16, 16),
])
def test_wkv6_sweep(b, h, s, dk, chunk):
    r = rng.normal(size=(b, h, s, dk)).astype(np.float32) * 0.5
    k = rng.normal(size=(b, h, s, dk)).astype(np.float32) * 0.5
    v = rng.normal(size=(b, h, s, dk)).astype(np.float32) * 0.5
    loga = -np.exp(rng.normal(size=(b, h, s, dk)).astype(np.float32) * 0.5)
    u = rng.normal(size=(h, dk)).astype(np.float32) * 0.5
    reset = np.zeros((b, s), bool)
    reset[:, 0] = True
    reset[0, s // 3] = True          # mid-chunk reset (regression: fp32
    reset[-1, s // 2 + 3] = True     # cancellation with -1e30 penalties)
    out = wkv6_forward(r, k, v, loga, u, reset, chunk=chunk)
    tr = lambda a: np.transpose(a, (0, 2, 1, 3))
    exp = ref.wkv6_ref(tr(r), tr(k), tr(v), tr(loga), u, reset)
    np.testing.assert_allclose(
        np.asarray(out), np.transpose(np.asarray(exp), (0, 2, 1, 3)),
        atol=5e-5, rtol=5e-4)
