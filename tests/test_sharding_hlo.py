"""Logical sharding rules (divisibility fallback) + HLO cost roll-up."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_cost import rollup
from repro.launch.mesh import make_local_mesh
from repro.sharding.logical import (
    DECODE_RULES, TRAIN_RULES, ShardingRules, use_rules,
)


class FakeMesh:
    """Shape-only stand-in so rule resolution is testable without devices."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_divisible_dims_shard():
    rules = ShardingRules(FakeMesh({"data": 16, "model": 16}), TRAIN_RULES)
    spec = rules.spec(("embed", "mlp"), (4096, 12288))
    assert spec == P(("data",), "model")


def test_indivisible_dims_fall_back_to_replication():
    rules = ShardingRules(FakeMesh({"data": 16, "model": 16}), TRAIN_RULES)
    # 40 rwkv heads / 24 granite heads cannot shard 16-way
    spec = rules.spec(("heads", None), (24, 64))
    assert spec == P(None, None)
    assert rules.dropped and rules.dropped[0][0] == "heads"


def test_multi_axis_prefix_fallback():
    # batch maps to (pod, data); batch=16 divides data(16) but not 32
    rules = ShardingRules(
        FakeMesh({"pod": 2, "data": 16, "model": 16}), TRAIN_RULES)
    spec = rules.spec(("batch", "seq"), (16, 128))
    assert spec == P("pod", None) or spec == P(("pod",), None)


def test_no_mesh_axis_used_twice():
    rules = ShardingRules(FakeMesh({"data": 4, "model": 4}), TRAIN_RULES)
    # expert and mlp both map to model; second one must not reuse it
    spec = rules.spec(("expert", "embed", "mlp"), (8, 64, 64))
    flat = []
    for s in spec:
        if s is None:
            continue
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


def test_decode_rules_shard_kv_seq():
    rules = ShardingRules(FakeMesh({"data": 16, "model": 16}),
                          DECODE_RULES)
    spec = rules.spec(("batch", "kv_seq", "act_kv_heads", None),
                      (128, 32768, 8, 128))
    assert spec[1] == "model" or spec[1] == ("model",)


def test_shard_noop_outside_context():
    from repro.sharding.logical import shard
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x


# ------------------------------------------------------------ hlo_cost
def test_rollup_exact_on_nested_scan():
    def nested(w, x):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    W = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    c = jax.jit(nested).lower(W, x).compile()
    r = rollup(c.as_text())
    assert r.flops == 2 * 8 * 64 * 64 * 15
    assert sorted(r.while_trips) == [3, 5]
    assert r.bytes > 0


def test_rollup_counts_collectives_through_loops():
    mesh = make_local_mesh()
    if mesh.devices.size < 1:
        pytest.skip("no devices")

    def fn(x):
        def body(h, _):
            return h @ x, None
        h, _ = jax.lax.scan(body, x, None, length=4)
        return h.sum()

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    c = jax.jit(fn).lower(x).compile()
    r = rollup(c.as_text())
    assert r.flops == 2 * 8 * 8 * 8 * 4
