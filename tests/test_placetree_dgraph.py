"""ClientPlaceTree parallelism transformations + DGraph lifecycle."""
import pytest

from repro.core.dgraph import DGraph
from repro.core.placetree import ClientPlaceTree


def tree4d():
    return ClientPlaceTree([("PP", 2), ("DP", 4), ("CP", 2), ("TP", 2)])


def test_coords_roundtrip():
    t = tree4d()
    for rank in range(t.world):
        assert t.rank_of(t.coords(rank)) == rank


def test_buckets_and_clients():
    t = tree4d()
    assert t.world == 32
    assert t.nodes_at("DP") == 8        # PP x DP
    assert t.buckets("DP", group_size=2) == 4
    assert t.nodes_at("WORLD") == 32
    clients = t.clients_under("DP", 0)
    assert len(clients) == 32 // 8
    # all clients of one bucket share PP and DP coords
    c0 = t.coords(clients[0])
    for c in clients[1:]:
        cc = t.coords(c)
        assert cc["PP"] == c0["PP"] and cc["DP"] == c0["DP"]


def test_client_views_pp_metadata_and_broadcast():
    t = tree4d()
    t.set_broadcast(["TP"])
    roles = {}
    for rank in range(t.world):
        v = t.client_view(rank, "DP")
        roles.setdefault(v.role, []).append(rank)
        c = t.coords(rank)
        if c["TP"] != 0:
            assert v.role == "none"          # suppressed by broadcast
        elif c["PP"] != 0:
            assert v.role == "metadata"      # pipeline stage > 0
        else:
            assert v.role == "data"
            assert v.cp_degree == 2 and v.cp_rank == c["CP"]
    # exactly PP0 x DP x CP clients fetch data
    assert len(roles["data"]) == 4 * 2
    # redundancy eliminated: data fetchers / world
    assert len(t.data_fetching_clients("DP")) == 8


def test_unknown_axis_raises():
    t = tree4d()
    with pytest.raises(KeyError):
        t.nodes_at("EP")
    with pytest.raises(KeyError):
        t.set_broadcast(["XX"])


def _meta(n):
    return [{"sample_id": f"s{i}", "source": f"src{i % 3}",
             "modality": "image" if i % 2 else "text",
             "text_tokens": 10 + i, "image_tokens": (i % 2) * 50}
            for i in range(n)]


def test_dgraph_lifecycle_and_lineage():
    g = DGraph.from_buffer(_meta(12))
    g.with_cost(lambda m: float(m["text_tokens"] ** 2))
    g.assign_buckets([i % 4 for i in range(12)])
    for b, nodes in g.by_bucket().items():
        g.assign_bins(nodes, [0] * len(nodes))
    lin = g.lineage("s3")
    kinds = [k for k, _ in lin]
    assert "cost" in kinds and "bucket" in kinds and "bin" in kinds
    dot = g.to_dot()
    assert "digraph" in dot and "s0" in dot


def test_dgraph_derive_shares_nodes():
    g = DGraph.from_buffer(_meta(10))
    img = g.derive("image", lambda m: m["image_tokens"] > 0)
    assert 0 < len(img) < len(g)
    img.with_cost(lambda m: float(m["image_tokens"]))
    # mutation visible through the parent graph (shared nodes)
    costed = [n for n in g.nodes if n.cost > 0]
    assert len(costed) == len(img)


def test_dgraph_select_view():
    g = DGraph.from_buffer(_meta(10), select=lambda m: m["modality"] == "text")
    assert all(n.meta["modality"] == "text" for n in g.nodes)


# -- orchestration transparency: lineage() / to_dot() ---------------------

def test_lineage_records_decisions_in_order():
    g = DGraph.from_buffer(_meta(8))
    g.mark(g.nodes, "selected", "mix")
    g.with_cost(lambda m: float(m["text_tokens"]))
    g.assign_buckets([i % 2 for i in range(8)])
    for b, nodes in g.by_bucket().items():
        g.assign_bins(nodes, [i % 2 for i in range(len(nodes))])
    lin = g.lineage("s5")
    kinds = [k for k, _ in lin]
    # every decision is reconstructable, in application order
    assert kinds == ["mix", "cost", "bucket", "bin"]
    by_kind = dict(lin)
    assert by_kind["cost"] == 15.0          # text_tokens of s5
    assert by_kind["bucket"] == 5 % 2
    assert by_kind["mix"] == "buffered"     # mark() records the prior state


def test_lineage_unknown_sample_raises():
    g = DGraph.from_buffer(_meta(3))
    with pytest.raises(KeyError):
        g.lineage("nope")


def test_lineage_visible_through_derived_view():
    g = DGraph.from_buffer(_meta(10))
    img = g.derive("image", lambda m: m["image_tokens"] > 0)
    img.with_cost(lambda m: float(m["image_tokens"]))
    sid = img.nodes[0].sample_id
    # derived views share nodes, so the parent sees the same lineage
    assert g.lineage(sid) == img.lineage(sid)
    assert ("cost", 50.0) in g.lineage(sid)


def test_to_dot_renders_states_and_membership():
    g = DGraph.from_buffer(_meta(6), name="step7")
    g.assign_buckets([0, 0, 1, 1, 2, 2])
    g.assign_bins(g.nodes, [0, 1, 0, 1, 0, 1])
    dot = g.to_dot()
    assert dot.startswith('digraph "step7" {') and dot.endswith("}")
    assert "binned" in dot                  # current state in the label
    assert "bucket=2 bin=1" in dot          # membership in the label
    for n in g.nodes:
        assert f"n{n.nid} [" in dot


def test_to_dot_max_nodes_truncates():
    g = DGraph.from_buffer(_meta(50))
    dot = g.to_dot(max_nodes=5)
    body = [ln for ln in dot.splitlines() if "[label=" in ln]
    assert len(body) == 5
    full = g.to_dot(max_nodes=100)
    assert len([ln for ln in full.splitlines() if "[label=" in ln]) == 50
