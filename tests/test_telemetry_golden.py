"""Golden-trace regression: a seeded 20-step planning run produces a
byte-stable canonical span tree.

The live Overlord is multithreaded (prefetch rings, supervision), so its
span interleaving is not deterministic.  This test drives the SAME data
plane — SourceLoader -> Planner -> DataConstructor — synchronously on
one thread through ``_SyncHandle``, making the canonical
(timestamp-stripped) span forest fully determined by the seeds.  Any
change to span names, nesting, or attribution shows up as a diff
against the checked-in golden; regenerate deliberately with

    pytest tests/test_telemetry_golden.py --update-golden
"""
import json
import os
from concurrent.futures import Future

import pytest

from repro.configs import get_config
from repro.core.constructor import DataConstructor
from repro.core.mixing import StaticSchedule
from repro.core.placetree import ClientPlaceTree
from repro.core.planner import Planner
from repro.core.source_loader import SourceLoader
from repro.core.strategies import STRATEGIES
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group
from repro.telemetry import Telemetry, canonical_spans

GOLDEN = os.path.join(os.path.dirname(__file__), "goldens",
                      "telemetry_span_tree.json")
STEPS = 20
N_SOURCES = 2


class _SyncHandle:
    """Actor-handle stand-in that dispatches on the caller's thread, so
    the span forest has ONE deterministic interleaving."""

    alive = True

    def __init__(self, actor):
        self._actor = actor
        self.name = getattr(actor, "name", type(actor).__name__)

    def call(self, method, *args, timeout=None, retry=None, **kwargs):
        return getattr(self._actor, method)(*args, **kwargs)

    def call_async(self, method, *args, **kwargs):
        """Fan-out compatible: an already-resolved Future, so the
        pipelined planner path stays single-threaded and deterministic
        here."""
        fut = Future()
        try:
            fut.set_result(getattr(self._actor, method)(*args, **kwargs))
        except Exception as e:       # pragma: no cover - exercised live
            fut.set_exception(e)
        return fut

    def cast(self, method, *args, **kwargs):
        getattr(self._actor, method)(*args, **kwargs)


def run_seeded_plane(tmpdir: str) -> list[dict]:
    tel = Telemetry(enabled=True, seed=0)
    paths = materialize_group(coyo_like_specs(N_SOURCES, seed=7), tmpdir)
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    sched = StaticSchedule({s: 1.0 for s in paths})

    loaders = {}
    for i, (src, path) in enumerate(sorted(paths.items())):
        loader = SourceLoader(src, path, (0, 1), workers=1,
                              buffer_target=64, seed=3, telemetry=tel)
        loader.name = f"loader:{src}:0of1"
        loader.on_start()
        loaders[loader.name] = _SyncHandle(loader)

    constructors = {
        b: _SyncHandle(DataConstructor(b, tree, seq_len=128,
                                       rows_per_microbatch=2, n_bins=1,
                                       telemetry=tel))
        for b in range(tree.buckets("DP"))}

    planner = Planner(
        tree, sched, STRATEGIES["backbone_balance"],
        dict(costfn=backbone_cost(get_config("qwen3-8b")), broadcast=(),
             n_bins=1),
        loaders=loaders, constructors=constructors,
        samples_per_step=8, seed=5, plan_ahead=2, telemetry=tel)
    for step in range(STEPS):
        planner.ensure_planned(step)
        # pipelined path: pull the frontier ahead like the Overlord's
        # plan-ahead nudge, so planner.pipeline spans are golden-covered
        planner.advance_to(step + 2)
    for h in loaders.values():
        h.call("on_stop")
    return canonical_spans(tel.tracer.finished())


def test_golden_span_tree(tmp_path, request):
    forest = run_seeded_plane(str(tmp_path / "sources"))
    if request.config.getoption("--update-golden"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w", encoding="utf-8") as f:
            json.dump(forest, f, indent=1, sort_keys=True)
            f.write("\n")
        pytest.skip("golden rewritten; rerun without --update-golden")
    assert os.path.exists(GOLDEN), \
        "golden missing; generate with --update-golden"
    with open(GOLDEN, encoding="utf-8") as f:
        expected = json.load(f)
    assert forest == expected, \
        "canonical span tree diverged from golden (span names, nesting " \
        "or attribution changed); regenerate with --update-golden if " \
        "this is intentional"


def test_sync_plane_is_deterministic(tmp_path):
    """Two same-seed runs in one process yield identical forests — the
    precondition that makes the golden meaningful."""
    a = run_seeded_plane(str(tmp_path / "a"))
    b = run_seeded_plane(str(tmp_path / "b"))
    assert a == b
