import os
import sys

# smoke tests and benches must see 1 device (the dry-run sets 512 itself)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite checked-in golden files instead of comparing")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_lm_batch(cfg, b=2, s=64, seed=0, n_segments=2, trailing_pad=4):
    """Packed batch with segments + trailing padding for any family."""
    r = np.random.default_rng(seed)
    tokens = r.integers(1, cfg.vocab_size, (b, s)).astype(np.int32)
    seg = np.ones((b, s), np.int32)
    bounds = np.linspace(0, s - trailing_pad, n_segments + 1).astype(int)
    for i in range(n_segments):
        seg[:, bounds[i]:bounds[i + 1]] = i + 1
    if trailing_pad:
        seg[:, -trailing_pad:] = 0
    pos = np.zeros((b, s), np.int32)
    for i in range(n_segments):
        width = bounds[i + 1] - bounds[i]
        pos[:, bounds[i]:bounds[i + 1]] = np.arange(width)
    labels = np.where(seg > 0, tokens, -1).astype(np.int32)
    batch = dict(tokens=tokens, segment_ids=seg, positions=pos,
                 labels=labels)
    if cfg.family == "vlm" and cfg.image_token_frac > 0:
        n = int(s * cfg.image_token_frac)
        batch["image_embeds"] = r.normal(
            size=(b, n, cfg.d_model)).astype(np.float32) * 0.02
        batch["image_positions"] = np.broadcast_to(
            np.arange(n, dtype=np.int32) * 2, (b, n)).copy()
    if cfg.family == "audio":
        batch["enc_embeds"] = r.normal(
            size=(b, cfg.encoder_frames, cfg.d_model)).astype(
            np.float32) * 0.02
    return batch


def all_reduced_configs():
    import importlib
    mods = ["qwen3_moe_30b_a3b", "granite_moe_3b_a800m", "granite_20b",
            "qwen3_8b", "yi_9b", "qwen3_32b", "zamba2_7b", "pixtral_12b",
            "whisper_medium", "rwkv6_3b"]
    return [importlib.import_module(f"repro.configs.{m}").reduced()
            for m in mods]
