"""Durable job-level recovery (docs/FAULT_TOLERANCE.md runbook):
crash-consistent blob framing, epoch manifests, fenced commits, GC
retention, and Overlord.resume() end-to-end — including deliberate
corruption with fallback to the previous epoch."""
import json
import os
import pickle

import pytest

from repro.chaos.ledger import DeliveryLedger
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.core.fault import (
    CheckpointCorruption, CheckpointStore, frame_blob, unframe_blob,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group

N_SOURCES = 2


class FakeHandle:
    """Just enough of an ActorHandle for CheckpointStore.maybe_save."""

    alive = True

    def __init__(self, state):
        self.state = state

    def call(self, method, *a, **k):
        assert method == "checkpoint_state"
        return self.state


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("rec_sources")
    return materialize_group(coyo_like_specs(N_SOURCES), str(root))


def mk(source_paths, start=True, **kw):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0 for i in range(N_SOURCES)})
    defaults = dict(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance", shadows=True, ledger=True,
        loader_ckpt_every=2,
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()))
    defaults.update(kw)
    ov = Overlord(source_paths, tree, sched, OverlordConfig(**defaults))
    return ov.start() if start else ov


def run_steps(ov, lo, hi, timeout=30.0):
    for step in range(lo, hi):
        for r in range(ov.tree.world):
            v = ov.get_batch(step, r, timeout=timeout)
            assert v["role"] in ("data", "metadata", "none")
        ov.step_done(step)


# ------------------------------------------------------------- framing
def test_frame_blob_roundtrip():
    payload = pickle.dumps({"step": 7, "state": list(range(100))})
    assert unframe_blob(frame_blob(payload)) == payload


def test_unframe_rejects_truncation_and_bitrot():
    framed = frame_blob(b"x" * 256)
    with pytest.raises(CheckpointCorruption, match="truncated"):
        unframe_blob(framed[:5])              # inside the header
    with pytest.raises(CheckpointCorruption, match="truncated"):
        unframe_blob(framed[:-10])            # payload cut short
    flipped = bytearray(framed)
    flipped[-1] ^= 0xFF
    with pytest.raises(CheckpointCorruption, match="checksum"):
        unframe_blob(bytes(flipped))          # bit rot
    with pytest.raises(CheckpointCorruption, match="magic"):
        unframe_blob(b"NOPE" + framed[4:])    # foreign file


# ------------------------------------------- restart-shaped store reads
def test_checkpointed_step_survives_restart(tmp_path):
    """S1 regression: a fresh process over the same root must size its
    replay window from the on-disk state, not report -1."""
    root = str(tmp_path / "ck")
    st = CheckpointStore(root, loader_every=1)
    st.maybe_save("loader", "loader:a", 4, FakeHandle({"cursor": 9}))
    st.commit_manifest(4)

    restarted = CheckpointStore(root, loader_every=1)
    assert restarted.checkpointed_step("loader:a") == 4
    out = restarted.load("loader:a")
    assert out == {"step": 4, "state": {"cursor": 9}}


def test_checkpointed_step_from_orphan_blob(tmp_path):
    """A save that never reached a manifest commit (crash between blob
    write and manifest rename) is still trusted when NO epoch exists —
    the blob is self-verifying."""
    root = str(tmp_path / "ck")
    st = CheckpointStore(root, loader_every=1)
    st.maybe_save("loader", "loader:a", 6, FakeHandle({"cursor": 1}))
    restarted = CheckpointStore(root, loader_every=1)
    assert restarted.checkpointed_step("loader:a") == 6
    assert restarted.load("loader:a")["step"] == 6


def test_legacy_flat_checkpoint_still_readable(tmp_path):
    root = str(tmp_path / "ck")
    os.makedirs(root)
    with open(os.path.join(root, "planner.ckpt"), "wb") as f:
        pickle.dump({"step": 3, "state": {"w": 1.0}}, f)
    st = CheckpointStore(root)
    assert st.checkpointed_step("planner") == 3
    assert st.load("planner")["state"] == {"w": 1.0}


# ------------------------------------------------- corruption handling
def test_corrupt_ckpt_is_counted_and_falls_back(tmp_path):
    """S2 regression: a truncated/corrupt .ckpt must not crash load();
    it is counted in stats() and the caller falls back."""
    root = str(tmp_path / "ck")
    os.makedirs(root)
    with open(os.path.join(root, "planner.ckpt"), "wb") as f:
        f.write(b"\x80\x04truncated-pickle")
    st = CheckpointStore(root)
    assert st.load("planner") is None          # no raise
    stats = st.stats()
    assert stats["load_failures"]["planner"] == 1
    assert "planner" in stats["last_failure"]


def test_corrupted_newest_epoch_falls_back(tmp_path):
    """Deliberate corruption of the newest epoch's blob: the loader must
    detect it (checksum) and resume from the previous epoch."""
    root = str(tmp_path / "ck")
    st = CheckpointStore(root, loader_every=1)
    st.maybe_save("loader", "loader:a", 2, FakeHandle({"gen": "old"}))
    assert st.commit_manifest(2) == 1
    st.maybe_save("loader", "loader:a", 4, FakeHandle({"gen": "new"}))
    assert st.commit_manifest(4) == 2

    # bit-rot the epoch-2 blob on disk
    man2 = json.load(open(os.path.join(root,
                                       "epoch-00000002.manifest.json")))
    blob = os.path.join(root, man2["actors"]["loader:a"]["blob"])
    data = bytearray(open(blob, "rb").read())
    data[-3] ^= 0xFF
    open(blob, "wb").write(bytes(data))

    restarted = CheckpointStore(root, loader_every=1)
    man = restarted.latest_manifest()
    assert man is not None and man["epoch"] == 1
    assert restarted.load("loader:a")["state"] == {"gen": "old"}
    assert restarted.stats()["manifest_fallbacks"] >= 1


# ---------------------------------------------------------------- fencing
def test_fence_blocks_zombie_commits(tmp_path):
    root = str(tmp_path / "ck")
    zombie = CheckpointStore(root, loader_every=1)
    zombie.acquire_fence()
    zombie.maybe_save("loader", "loader:a", 1, FakeHandle({"v": 1}))
    assert zombie.commit_manifest(1) == 1

    successor = CheckpointStore(root, loader_every=1)
    assert successor.acquire_fence() > zombie.fence_token

    # the zombie can no longer publish state: blob writes are skipped,
    # manifest and cut commits refused, all counted
    assert zombie.maybe_save("loader", "loader:a", 2,
                             FakeHandle({"v": 2})) is False
    assert zombie.commit_manifest(2) is None
    assert zombie.commit_cut(
        2, {"frontier": 2, "planner": {"x": 1}, "actors": {},
            "ledger": None}) is None
    assert zombie.stats()["fenced_writes"] >= 3

    # the successor commits fine and never sees the zombie's state
    successor.maybe_save("loader", "loader:a", 3, FakeHandle({"v": 3}))
    assert successor.commit_manifest(3) == 2
    fresh = CheckpointStore(root, loader_every=1)
    assert fresh.load("loader:a")["state"] == {"v": 3}
    assert fresh.stats()["fenced_writes"] == 0


# --------------------------------------------------------------------- GC
def test_gc_retains_keep_epochs(tmp_path):
    root = str(tmp_path / "ck")
    st = CheckpointStore(root, loader_every=1, keep_epochs=2)
    for s in range(5):
        st.maybe_save("loader", "loader:a", s, FakeHandle({"s": s}))
        st.commit_manifest(s)
    manifests = sorted(fn for fn in os.listdir(root)
                       if fn.endswith(".manifest.json"))
    assert manifests == ["epoch-00000004.manifest.json",
                         "epoch-00000005.manifest.json"]
    # every retained epoch still fully loads; GC removed older blobs
    restarted = CheckpointStore(root)
    assert restarted.latest_manifest()["epoch"] == 5
    assert restarted.load("loader:a")["state"] == {"s": 4}
    blobs = os.listdir(os.path.join(root, "blobs"))
    assert not any("@0." in fn or "@1." in fn or "@2." in fn
                   for fn in blobs), blobs


# -------------------------------------------------------- ledger snapshot
def test_ledger_snapshot_roundtrip():
    led = DeliveryLedger()
    led.record_planned(0, "a/1", "src_a", 0)
    led.record_planned(0, "a/2", "src_a", 0)
    led.record_delivered(0, 0, 0, {"a/1"})
    led.record_delivered(0, 1, 0, {"a/1"})
    led.record_dropped(0, "a/2", "packing_overflow")
    led.record_quarantined("a/3", "src_a", "bad_crc")
    snap = led.snapshot()

    clone = DeliveryLedger()
    clone.restore(snap)
    assert clone.snapshot() == snap
    assert clone.delivered_ids() == {"a/1"}
    assert clone.verify(strict=False)["ok"] \
        == led.verify(strict=False)["ok"]


# ----------------------------------------------------- overlord end-to-end
def test_resume_continues_after_process_death(source_paths, tmp_path):
    kw = dict(checkpoint_dir=str(tmp_path / "job_ck"))
    ov = mk(source_paths, **kw)
    run_steps(ov, 0, 6)
    ov.simulate_process_death()

    ov2 = mk(source_paths, start=False, **kw).resume()
    try:
        rep = ov2.resume_report
        assert rep is not None and not rep["cold_start"]
        assert rep["epoch"] >= 1
        assert rep["step"] == 5
        assert "planner" in rep["restored"] and "ledger" in rep["restored"]
        run_steps(ov2, rep["step"] + 1, rep["step"] + 5)
        summary = ov2.ledger.verify(strict=True)
        assert summary["ok"] and summary["lost"] == [] \
            and summary["duplicates"] == {}
        assert ov2.store.stats()["manifests_committed"] > 0
    finally:
        ov2.shutdown()


def test_resume_with_no_epochs_cold_starts(source_paths, tmp_path):
    kw = dict(checkpoint_dir=str(tmp_path / "empty_ck"))
    ov = mk(source_paths, start=False, **kw).resume()
    try:
        assert ov.resume_report["cold_start"]
        run_steps(ov, 0, 2)
    finally:
        ov.shutdown()


def test_resume_falls_back_past_corrupt_newest_epoch(source_paths,
                                                     tmp_path):
    """The acceptance criterion: a deliberately corrupted blob in the
    newest epoch is detected and resume proceeds from the previous
    epoch, replaying forward without loss or duplication."""
    ckdir = str(tmp_path / "cor_ck")
    kw = dict(checkpoint_dir=ckdir)
    ov = mk(source_paths, **kw)
    run_steps(ov, 0, 6)
    ov.simulate_process_death()

    # corrupt ALL of the newest epoch's planner blob (checksum breaks)
    files = sorted(fn for fn in os.listdir(ckdir)
                   if fn.endswith(".manifest.json"))
    newest = json.load(open(os.path.join(ckdir, files[-1])))
    blob = os.path.join(ckdir, newest["actors"]["planner"]["blob"])
    open(blob, "wb").write(b"garbage" * 16)

    ov2 = mk(source_paths, start=False, **kw).resume()
    try:
        rep = ov2.resume_report
        assert not rep["cold_start"]
        assert rep["epoch"] < newest["epoch"]
        assert rep["step"] < 5
        assert ov2.store.stats()["manifest_fallbacks"] >= 1
        run_steps(ov2, rep["step"] + 1, 8)
        summary = ov2.ledger.verify(strict=True)
        assert summary["ok"] and summary["lost"] == [] \
            and summary["duplicates"] == {}
    finally:
        ov2.shutdown()
