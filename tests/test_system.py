"""End-to-end behaviour tests for the OVERLORD data plane."""
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.core.colocated import ColocatedFleet
from repro.data.cost_models import backbone_cost, encoder_cost
from repro.data.sources import coyo_like_specs, materialize_group


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("sources")
    return materialize_group(coyo_like_specs(4), str(root))


def mk_overlord(source_paths, strategy="backbone_balance", tree=None,
                **cfg_kw):
    tree = tree or ClientPlaceTree([("PP", 1), ("DP", 4), ("CP", 1),
                                    ("TP", 2)])
    cfg = get_config("qwen3-8b")
    if strategy == "hybrid_balance":
        params = dict(backbone_costfn=backbone_cost(cfg),
                      encoder_costfn=encoder_cost(48, 1664),
                      broadcast=("TP",))
    elif strategy == "backbone_balance":
        params = dict(costfn=backbone_cost(cfg), broadcast=("TP",))
    else:
        params = dict(costfn=backbone_cost(cfg))
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0 for i in range(4)})
    ov = Overlord(source_paths, tree, sched, OverlordConfig(
        seq_len=512, rows_per_microbatch=2, n_bins=2, strategy=strategy,
        strategy_params=params, **cfg_kw))
    return ov.start()


def test_delivery_and_balancing(source_paths):
    ov = mk_overlord(source_paths, "backbone_balance")
    try:
        for step in range(5):
            views = [ov.get_batch(step, r) for r in range(ov.tree.world)]
            data = [v for v in views if v["role"] == "data"]
            none = [v for v in views if v["role"] == "none"]
            assert len(data) == 4          # DP=4 x TP0 only (broadcast)
            assert len(none) == 4          # TP=1 suppressed
            for v in data:
                assert len(v["bins"]) == 2
                assert v["bins"][0].tokens.shape == (2, 512)
            ov.step_done(step)
        diags = ov.diagnostics()
        im = [d["balance:main"]["imbalance"] for d in diags]
        assert all(i < 1.6 for i in im)    # balanced buckets
    finally:
        ov.shutdown()


def test_balanced_beats_vanilla_imbalance(source_paths):
    res = {}
    for strat in ("vanilla", "backbone_balance"):
        ov = mk_overlord(source_paths, strat)
        try:
            for step in range(4):
                for r in range(ov.tree.world):
                    ov.get_batch(step, r)
                ov.step_done(step)
            ds = ov.diagnostics()
            res[strat] = np.mean(
                [d["balance:main"]["imbalance"] for d in ds])
        finally:
            ov.shutdown()
    assert res["backbone_balance"] < res["vanilla"]


def test_cp_slices_partition_sequence(source_paths):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 2), ("TP", 1)])
    ov = mk_overlord(source_paths, "backbone_balance", tree=tree)
    try:
        views = [ov.get_batch(0, r) for r in range(tree.world)]
        by_dp = {}
        for r, v in enumerate(views):
            c = tree.coords(r)
            if v["role"] == "data":
                by_dp.setdefault(c["DP"], {})[c["CP"]] = v
        for dp, cps in by_dp.items():
            assert set(cps) == {0, 1}
            full = 512
            got = cps[0]["bins"][0].tokens.shape[1] \
                + cps[1]["bins"][0].tokens.shape[1]
            assert got == full
            # zig-zag slices are disjoint in content ordering: token
            # multisets of the two slices partition the packed row
            t0 = cps[0]["bins"][0].tokens.flatten()
            t1 = cps[1]["bins"][0].tokens.flatten()
            assert len(t0) == len(t1) == full // 2 * 2  # rows=2
        ov.step_done(0)
    finally:
        ov.shutdown()


def test_memory_smaller_than_colocated(tmp_path):
    """The core §7.2 claim at test scale: per-source loaders beat
    per-rank x all-source colocated loaders on resident bytes.  The gap
    widens with sources x ranks x workers (Figs. 4/14); we use a scale
    where all three dimensions are non-trivial."""
    from repro.data.sources import navit_like_specs
    paths = materialize_group(
        [s.__class__(**{**s.__dict__, "n_samples": 256})
         for s in navit_like_specs(12)], str(tmp_path))
    dp, workers = 16, 8
    tree = ClientPlaceTree([("PP", 1), ("DP", dp), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({n: 1.0 for n in paths})
    from repro.core.autoscale import PartitionLimits
    ov = Overlord(paths, tree, sched, OverlordConfig(
        seq_len=512, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance",
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()),
        shadows=False, buffer_target=64,
        limits=PartitionLimits(total_workers=16, w_actor=2),
    )).start()
    try:
        for step in range(2):
            for r in range(ov.tree.world):
                ov.get_batch(step, r)
            ov.step_done(step)
        ov_mem = ov.memory_report()["total_ex_shadows"]
    finally:
        ov.shutdown()
    fleet = ColocatedFleet(paths, dp, workers, 512, 2, sched)
    co_mem = fleet.memory_bytes()
    fleet.close()
    assert ov_mem < co_mem, (ov_mem, co_mem)


def test_curriculum_mixture_shifts(source_paths):
    from repro.core import CurriculumSchedule
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = CurriculumSchedule(
        easy={"coyo_000": 1.0}, hard={"coyo_003": 1.0}, ramp_steps=8)
    ov = Overlord(source_paths, tree, sched, OverlordConfig(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance",
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()),
    )).start()
    try:
        for step in range(9):
            for r in range(tree.world):
                ov.get_batch(step, r)
            ov.step_done(step)
        diags = ov.planner.call("diagnostics")
        # weights recorded in planner history shift easy -> hard
        hist = ov.planner.call("history_window")
        first = min(hist)
    finally:
        ov.shutdown()
    w0 = sched.weights(0)
    w8 = sched.weights(8)
    assert w0["coyo_000"] > 0.9 and w8["coyo_003"] > 0.9
