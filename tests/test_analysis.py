"""repro.analysis: static data-plane linter + actor-concurrency analyzer.

Each rule family gets at least one true-positive (seeded-bad input is
caught) and one true-negative (shipped/valid input is clean).
"""
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (
    AnalysisError, Report, Severity, lint_actor_source, lint_dgraph,
    lint_model_config, lint_observability_source, lint_overlord_config,
    lint_perf_source, lint_shipped_model_configs, lint_strategies,
    lint_strategy, validate_launch,
)
from repro.analysis.lint import main as lint_main
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.core.dgraph import BINNED, DGraph, SELECTED
from repro.core.primitives import LoadingPlan
from repro.core.strategies import STRATEGIES
from repro.data.cost_models import backbone_cost


def _meta(n):
    return [{"sample_id": f"s{i}", "source": f"src{i % 2}",
             "text_tokens": 8 + i, "image_tokens": 0} for i in range(n)]


def tree4():
    return ClientPlaceTree([("PP", 1), ("DP", 4), ("CP", 1), ("TP", 1)])


def rules(report):
    return {f.rule for f in report.findings}


# =====================================================================
# pipeline family: DGraph lifecycle (DG1xx)
# =====================================================================

def full_lifecycle_graph(n=8, buckets=2, bins=2):
    g = DGraph.from_buffer(_meta(n))
    g.mark(g.nodes, SELECTED, "mix")
    g.with_cost(lambda m: float(m["text_tokens"]))
    g.assign_buckets([i % buckets for i in range(n)])
    for _, nodes in g.by_bucket().items():
        g.assign_bins(nodes, [i % bins for i in range(len(nodes))])
    return g


def test_dgraph_clean_lifecycle_is_clean():          # true negative
    g = full_lifecycle_graph()
    rep = lint_dgraph(g, n_buckets=2, n_bins=2)
    assert rep.ok and len(rep) == 0


def test_dgraph_detects_state_regression():          # DG102
    g = full_lifecycle_graph()
    g.mark(g.nodes[:1], SELECTED, "mix")             # BINNED -> SELECTED
    rep = lint_dgraph(g)
    assert "DG102" in rules(rep) and not rep.ok


def test_dgraph_detects_unknown_state():             # DG101
    g = DGraph.from_buffer(_meta(2))
    g.nodes[0].state = "teleported"
    rep = lint_dgraph(g)
    assert "DG101" in rules(rep)


def test_dgraph_detects_orphan_membership():         # DG103
    g = DGraph.from_buffer(_meta(4))
    g.mark(g.nodes, SELECTED, "mix")
    g.nodes[0].bin = 1                               # bin without bucket
    rep = lint_dgraph(g)
    assert "DG103" in rules(rep)
    g2 = full_lifecycle_graph()
    g2.nodes[0].bucket = None                        # BINNED but no bucket
    assert "DG103" in rules(lint_dgraph(g2))


def test_dgraph_detects_out_of_range_bucket():       # DG104
    g = full_lifecycle_graph(buckets=4)
    rep = lint_dgraph(g, n_buckets=2)
    assert "DG104" in rules(rep)
    assert lint_dgraph(g, n_buckets=4, n_bins=2).ok


def test_dgraph_detects_parent_cycle_and_dangling():  # DG105 / DG106
    g = DGraph.from_buffer(_meta(3))
    a, b, c = g.nodes
    a.parents = [b.nid]
    b.parents = [a.nid]
    rep = lint_dgraph(g)
    assert "DG105" in rules(rep)
    g2 = DGraph.from_buffer(_meta(2))
    g2.nodes[0].parents = [999]
    assert "DG106" in rules(lint_dgraph(g2))


def test_dgraph_detects_duplicate_sample_ids():      # DG107
    meta = _meta(3)
    meta[2]["sample_id"] = meta[0]["sample_id"]
    rep = lint_dgraph(DGraph.from_buffer(meta))
    assert "DG107" in rules(rep)


def test_dgraph_flags_stragglers():                  # DG108
    g = full_lifecycle_graph(n=6)
    late = DGraph.from_buffer([{"sample_id": "late", "source": "src0",
                                "text_tokens": 9, "image_tokens": 0}])
    late.nodes[0].nid = 99
    late.mark(late.nodes, SELECTED, "mix")
    g.nodes.append(late.nodes[0])                    # stuck at SELECTED
    rep = lint_dgraph(g)
    assert "DG108" in {f.rule for f in rep.warnings}
    assert rep.ok                                    # warning, not error


# =====================================================================
# pipeline family: strategy contracts (ST2xx)
# =====================================================================

def test_shipped_strategies_are_clean():             # true negative
    rep = lint_strategies(STRATEGIES)
    assert rep.ok and len(rep) == 0


def test_strategy_bad_signature():                   # ST201
    def bad(ctx, schedule, total):                   # not keyword-only
        ctx.mix(schedule, total)
        return ctx.plan()
    rep = lint_strategy("bad", bad)
    assert "ST201" in rules(rep)

    def no_total(ctx, *, schedule) -> LoadingPlan:
        ctx.mix(schedule, 1)
        return ctx.plan()
    assert "ST201" in rules(lint_strategy("no_total", no_total))


def test_strategy_missing_mix():                     # ST204
    def skips_mix(ctx, *, schedule, total) -> LoadingPlan:
        ctx.dgraph("main")
        ctx.distribute("DP")
        return ctx.plan()
    rep = lint_strategy("skips_mix", skips_mix)
    assert "ST204" in rules(rep)


def test_strategy_balance_before_distribute():       # ST205
    def inverted(ctx, *, schedule, total, costfn) -> LoadingPlan:
        ctx.mix(schedule, total)
        g = ctx.dgraph("main")
        ctx.cost(costfn, g)
        ctx.balance("greedy_binpack", graph=g)
        ctx.distribute("DP")
        return ctx.plan(g)
    rep = lint_strategy("inverted", inverted)
    assert "ST205" in rules(rep)


def test_strategy_unknown_primitive_typo():          # ST206
    def typo(ctx, *, schedule, total) -> LoadingPlan:
        ctx.mix(schedule, total)
        ctx.distrbute("DP")                          # typo
        return ctx.plan()
    rep = lint_strategy("typo", typo)
    assert "ST206" in rules(rep)


def test_strategy_wrong_return_shape():              # ST203
    def returns_list(ctx, *, schedule, total) -> LoadingPlan:
        ctx.mix(schedule, total)
        return [1, 2, 3]
    rep = lint_strategy("returns_list", returns_list)
    assert "ST203" in rules(rep)


# =====================================================================
# config family (CFG3xx / MDL4xx)
# =====================================================================

def good_overlord_cfg(**kw):
    base = dict(strategy="backbone_balance",
                strategy_params=dict(
                    costfn=backbone_cost(get_config("qwen3-8b")),
                    broadcast=()))
    base.update(kw)
    return OverlordConfig(**base)


def test_shipped_style_overlord_config_is_clean():   # true negative
    rep = lint_overlord_config(good_overlord_cfg(), tree=tree4(),
                               n_sources=4)
    assert rep.ok and len(rep) == 0


def test_config_bad_fill_factor_and_dims():          # CFG301 / CFG302
    rep = lint_overlord_config(good_overlord_cfg(fill_factor=1.5,
                                                 seq_len=0))
    assert {"CFG301", "CFG302"} <= rules(rep)


def test_config_unknown_strategy():                  # CFG303
    rep = lint_overlord_config(OverlordConfig(strategy="nope"))
    assert "CFG303" in rules(rep)


def test_config_missing_and_unknown_strategy_params():   # CFG304
    rep = lint_overlord_config(OverlordConfig(
        strategy="backbone_balance", strategy_params={}))
    assert "CFG304" in rules(rep)
    rep2 = lint_overlord_config(good_overlord_cfg(
        strategy_params=dict(
            costfn=backbone_cost(get_config("qwen3-8b")),
            not_a_param=1)))
    assert "CFG304" in rules(rep2)


def test_config_unknown_axis_needs_tree():           # CFG305
    cfg = good_overlord_cfg()
    cfg.strategy_params["axis"] = "EP"
    assert lint_overlord_config(cfg).ok              # no tree: not checkable
    rep = lint_overlord_config(cfg, tree=tree4())
    assert "CFG305" in rules(rep)


def test_config_capacity_overflow_warns():           # CFG306
    rep = lint_overlord_config(
        good_overlord_cfg(samples_per_step=10_000, seq_len=128,
                          rows_per_microbatch=1, n_bins=1),
        tree=tree4())
    assert "CFG306" in {f.rule for f in rep.warnings}
    rep2 = lint_overlord_config(good_overlord_cfg(samples_per_step=2,
                                                  n_bins=2), tree=tree4())
    assert "CFG306" in {f.rule for f in rep2.errors}  # can't fill bins


def test_config_buffer_starvation_warns():           # CFG307
    rep = lint_overlord_config(
        good_overlord_cfg(samples_per_step=512, buffer_target=16),
        tree=tree4(), n_sources=2)
    assert "CFG307" in {f.rule for f in rep.warnings}


def test_config_inverted_ckpt_frequencies():         # CFG308
    rep = lint_overlord_config(good_overlord_cfg(
        planner_ckpt_every=8, loader_ckpt_every=1))
    assert "CFG308" in {f.rule for f in rep.warnings}
    rep2 = lint_overlord_config(good_overlord_cfg(planner_ckpt_every=0))
    assert "CFG308" in {f.rule for f in rep2.errors}


def test_config_resilience_knobs():                  # CFG309
    from repro.core import RetryPolicy
    assert lint_overlord_config(good_overlord_cfg()).ok  # true negative
    rep = lint_overlord_config(good_overlord_cfg(
        retry=RetryPolicy(max_attempts=0)))
    assert "CFG309" in {f.rule for f in rep.errors}
    rep2 = lint_overlord_config(good_overlord_cfg(
        retry=RetryPolicy(base_delay_s=2.0, max_delay_s=0.1)))
    assert "CFG309" in {f.rule for f in rep2.errors}
    rep3 = lint_overlord_config(good_overlord_cfg(
        breaker_failures=0, breaker_cooldown_s=-1.0, dlq_capacity=0))
    assert len([f for f in rep3.errors if f.rule == "CFG309"]) == 3


def test_config_pipelining_knobs():                  # CFG310
    assert lint_overlord_config(good_overlord_cfg()).ok  # true negative
    assert lint_overlord_config(
        good_overlord_cfg(plan_ahead=0)).ok  # pipelining off is valid
    rep = lint_overlord_config(good_overlord_cfg(plan_ahead=-1))
    assert "CFG310" in {f.rule for f in rep.errors}
    rep2 = lint_overlord_config(good_overlord_cfg(plan_ahead=2,
                                                  prefetch=0))
    assert "CFG310" in {f.rule for f in rep2.warnings}


def test_config_recovery_knobs():                    # CFG311
    assert lint_overlord_config(good_overlord_cfg()).ok  # true negative
    rep = lint_overlord_config(good_overlord_cfg(manifest_every=0))
    assert "CFG311" in {f.rule for f in rep.errors}
    rep2 = lint_overlord_config(good_overlord_cfg(keep_epochs=0))
    assert "CFG311" in {f.rule for f in rep2.errors}
    rep3 = lint_overlord_config(good_overlord_cfg(
        checkpoint_dir="/tmp/ck", manifest_every=3, loader_ckpt_every=8))
    assert "CFG311" in {f.rule for f in rep3.warnings}
    assert lint_overlord_config(good_overlord_cfg(
        checkpoint_dir="/tmp/ck", manifest_every=4,
        loader_ckpt_every=8)).ok  # aligned cadences


def test_all_shipped_model_configs_clean():          # true negative
    rep = lint_shipped_model_configs()
    assert rep.ok, rep.as_text()
    assert len(rep) == 0


def test_model_config_bad_geometry_and_moe():        # MDL401/402/403
    bad = get_config("qwen3-8b").replace(
        name="bad", head_dim=0, d_model=100, num_heads=3, num_kv_heads=2,
        num_experts=4, experts_per_token=8)
    rep = lint_model_config(bad)
    assert {"MDL401", "MDL402", "MDL403"} <= rules(rep)


def test_model_config_bad_enums():                   # MDL404 / MDL405
    bad = get_config("qwen3-8b").replace(
        name="bad2", family="quantum", dtype="float8", remat="everything")
    rep = lint_model_config(bad)
    assert {"MDL404", "MDL405"} <= rules(rep)


# =====================================================================
# actor-concurrency family (ACT5xx)
# =====================================================================

GOOD_ACTOR = """
from repro.core.actors import Actor

class Fine(Actor):
    def __init__(self):
        self.count = 0

    def bump(self, peer):
        self.count += 1
        return peer.call("ping", timeout=5)

    def checkpoint_state(self):
        return {"count": self.count}

    def restore_state(self, state):
        self.count = state["count"]
"""


def test_shipped_actor_sources_are_clean():          # true negative
    rep = lint_actor_source(GOOD_ACTOR, "good.py")
    assert rep.ok and len(rep) == 0
    for mod in ("source_loader", "planner", "constructor"):
        with open(f"src/repro/core/{mod}.py") as f:
            rep = lint_actor_source(f.read(), mod)
        assert rep.ok, rep.as_text()


def test_actor_thread_mutating_state():              # ACT501
    src = textwrap.dedent("""
        import threading
        from repro.core.actors import Actor

        class Racy(Actor):
            def on_start(self):
                self.items = []
                def pump():
                    self.items = ["x"]
                threading.Thread(target=pump, daemon=True).start()
    """)
    rep = lint_actor_source(src, "racy.py")
    assert "ACT501" in {f.rule for f in rep.errors}


def test_actor_thread_via_self_method_target():      # ACT501
    src = textwrap.dedent("""
        import threading
        from repro.core.actors import Actor

        class Racy2(Actor):
            def on_start(self):
                threading.Thread(target=self._loop).start()

            def _loop(self):
                self.tick = 1
    """)
    rep = lint_actor_source(src, "racy2.py")
    assert "ACT501" in {f.rule for f in rep.errors}


def test_actor_lock_smell():                         # ACT502
    src = textwrap.dedent("""
        import threading
        from repro.core.actors import Actor

        class Locked(Actor):
            def on_start(self):
                self.lock = threading.Lock()
    """)
    rep = lint_actor_source(src, "locked.py")
    assert "ACT502" in {f.rule for f in rep.warnings}


def test_actor_self_call_deadlock():                 # ACT503
    src = textwrap.dedent("""
        from repro.core.actors import Actor

        class Deadlock(Actor):
            def __init__(self, runtime):
                self.runtime = runtime

            def poke(self):
                return self.runtime.get(self.name).call("poke")
    """)
    rep = lint_actor_source(src, "deadlock.py")
    assert "ACT503" in {f.rule for f in rep.errors}

    src2 = textwrap.dedent("""
        from repro.core.actors import Actor

        class Deadlock2(Actor):
            def poke(self):
                return self.self_handle.call("poke")
    """)
    assert "ACT503" in rules(lint_actor_source(src2, "deadlock2.py"))


def test_actor_unbounded_call():                     # ACT504
    src = textwrap.dedent("""
        from repro.core.actors import Actor

        class Forever(Actor):
            def fetch(self, peer):
                return peer.call("slow", timeout=None)
    """)
    rep = lint_actor_source(src, "forever.py")
    assert "ACT504" in {f.rule for f in rep.errors}


def test_actor_half_checkpoint_pair():               # ACT505
    src = textwrap.dedent("""
        from repro.core.actors import Actor

        class Half(Actor):
            def checkpoint_state(self):
                return {}
    """)
    rep = lint_actor_source(src, "half.py")
    assert "ACT505" in {f.rule for f in rep.errors}


def test_actor_checkpoint_key_never_restored():      # ACT507
    src = textwrap.dedent("""
        from repro.core.actors import Actor

        class Lossy(Actor):
            def checkpoint_state(self):
                return {"cursor": self.cursor, "buffer": self.buffer}

            def restore_state(self, state):
                self.cursor = state["cursor"]
    """)
    rep = lint_actor_source(src, "lossy.py")
    errs = [f for f in rep.errors if f.rule == "ACT507"]
    assert errs and "'buffer'" in errs[0].message


def test_actor_roundtrip_checkpoint_clean():         # ACT507 true negative
    src = textwrap.dedent("""
        from repro.core.actors import Actor

        class Full(Actor):
            def checkpoint_state(self):
                return {"cursor": self.cursor, "buffer": self.buffer}

            def restore_state(self, state):
                self.cursor = state["cursor"]
                self.buffer = list(state.get("buffer", []))
    """)
    assert lint_actor_source(src, "full.py").ok


def test_actor_wholesale_restore_not_flagged():      # ACT507 opt-out shape
    src = textwrap.dedent("""
        from repro.core.actors import Actor

        class Wholesale(Actor):
            def checkpoint_state(self):
                return {"cursor": self.cursor, "buffer": self.buffer}

            def restore_state(self, state):
                self.__dict__.update(state)
    """)
    # generic consumption reads every key; nothing provably unread
    assert lint_actor_source(src, "wholesale.py").ok


def test_shipped_actors_roundtrip_clean():           # ACT507 true negative
    import os
    from repro.analysis.actor_lint import lint_actor_paths
    core_dir = os.path.join(os.path.dirname(__file__), os.pardir,
                            "src", "repro", "core")
    rep = lint_actor_paths([os.path.abspath(core_dir)])
    assert "ACT507" not in rules(rep), rep.as_text()


BARE_CALL = textwrap.dedent("""
    class Supervisor:
        def recover(self, handle):
            return handle.call("restore_state", {})
""")


def test_bare_call_in_core_flagged():                # ACT506
    rep = lint_actor_source(BARE_CALL, "src/repro/core/supervisor.py")
    assert "ACT506" in {f.rule for f in rep.warnings}


def test_bare_call_outside_core_not_flagged():       # ACT506 scope
    assert lint_actor_source(BARE_CALL, "src/repro/chaos/driver.py").ok
    # the actor runtime itself is exempt: it implements the mechanism
    assert lint_actor_source(BARE_CALL, "src/repro/core/actors.py").ok


def test_guarded_calls_in_core_not_flagged():        # ACT506 true negative
    src = textwrap.dedent("""
        class Supervisor:
            def recover(self, handle, policy):
                handle.call("restore_state", {}, retry=policy)
                try:
                    handle.call("replay", [])
                except Exception:
                    pass

            def dynamic(self, handle, method):
                return handle.call(method)   # non-literal: out of scope
    """)
    assert lint_actor_source(src, "src/repro/core/supervisor.py").ok


def test_call_in_try_orelse_still_flagged():         # ACT506 orelse gap
    src = textwrap.dedent("""
        class Supervisor:
            def recover(self, handle):
                try:
                    x = 1
                except Exception:
                    pass
                else:
                    handle.call("replay", [])   # runs unguarded
    """)
    rep = lint_actor_source(src, "src/repro/core/supervisor.py")
    assert "ACT506" in {f.rule for f in rep.warnings}


def test_shipped_core_modules_have_no_bare_calls():  # ACT506 repo-wide
    import os
    from repro.analysis.actor_lint import lint_actor_file
    import repro.core as core_pkg
    core_dir = os.path.dirname(core_pkg.__file__)
    rep = Report()
    for fn in sorted(os.listdir(core_dir)):
        if fn.endswith(".py"):
            lint_actor_file(os.path.join(core_dir, fn), rep)
    assert "ACT506" not in rules(rep), rep.as_text()


# =====================================================================
# observability family (OBS6xx)
# =====================================================================

FOREIGN_COUNTER = textwrap.dedent("""
    class Loader:
        def quarantine(self, sid):
            self.dlq._total += 1          # another object's books
            self.dlq._counts[sid] += 1    # subscripted, same problem
""")


def test_foreign_counter_write_flagged():            # OBS601
    rep = lint_observability_source(
        FOREIGN_COUNTER, "src/repro/core/source_loader.py")
    assert len([f for f in rep.warnings if f.rule == "OBS601"]) == 2


def test_foreign_counter_outside_core_not_flagged():  # OBS601 scope
    assert lint_observability_source(
        FOREIGN_COUNTER, "src/repro/chaos/driver.py").ok


def test_own_counters_and_registry_clean():          # OBS601 true negative
    src = textwrap.dedent("""
        class Constructor:
            def drop(self, src):
                self._dropped += 1            # own books: fine
                self._over_count[src] += 1    # own, subscripted: fine
                self.telemetry.inc("constructor_dropped_total", 1.0)
                depth = self.dlq.stats()["held"]   # read via API: fine
                return depth
    """)
    assert lint_observability_source(
        src, "src/repro/core/constructor.py").ok


def test_shipped_core_modules_obs_clean():           # OBS601 repo-wide
    import os
    from repro.analysis import lint_observability_file
    import repro.core as core_pkg
    core_dir = os.path.dirname(core_pkg.__file__)
    rep = Report()
    for fn in sorted(os.listdir(core_dir)):
        if fn.endswith(".py"):
            lint_observability_file(os.path.join(core_dir, fn), rep)
    assert "OBS601" not in rules(rep), rep.as_text()


# =====================================================================
# performance family (PERF7xx)
# =====================================================================

SERIAL_LOOP = textwrap.dedent("""
    def collect(handles):
        out = {}
        for name, h in handles.items():
            out[name] = h.call("snapshot", timeout=10)
        return out
""")


def test_serial_handle_loop_flagged():               # PERF701
    rep = lint_perf_source(SERIAL_LOOP, "src/repro/core/planner.py")
    assert "PERF701" in {f.rule for f in rep.warnings}


def test_serial_loop_outside_core_not_flagged():     # PERF701 scope
    assert lint_perf_source(SERIAL_LOOP, "src/repro/chaos/driver.py").ok
    # the actor runtime is exempt: FanOut's gather loop lives there
    assert lint_perf_source(SERIAL_LOOP, "src/repro/core/actors.py").ok


def test_serial_loop_true_negatives():               # PERF701 true negative
    src = textwrap.dedent("""
        def pipelined(handles, planner):
            out = {}
            # annotated on the loop header: intentional baseline
            for name, h in handles.items():   # perf: serial ok
                out[name] = h.call("snapshot", timeout=10)
            # async fan-out: does not block per handle
            futs = {}
            for name, h in handles.items():
                futs[name] = h.call_async("snapshot")
            # fixed receiver inside a step loop: one RPC per step,
            # not per handle
            for step in range(4):
                planner.call("ensure_planned", step, timeout=30)
            return out, futs
    """)
    assert lint_perf_source(src, "src/repro/core/planner.py").ok


def test_serial_loop_annotation_above_call():        # PERF701 opt-out
    src = textwrap.dedent("""
        def report(handles):
            health = {}
            for name, h in handles.items():
                # perf: serial ok — operator introspection
                health[name] = h.call("health", timeout=10)
            return health
    """)
    assert lint_perf_source(src, "src/repro/core/orchestrator.py").ok


def test_shipped_core_modules_perf_clean():          # PERF701 repo-wide
    import os
    from repro.analysis import lint_perf_file
    import repro.core as core_pkg
    core_dir = os.path.dirname(core_pkg.__file__)
    rep = Report()
    for fn in sorted(os.listdir(core_dir)):
        if fn.endswith(".py"):
            lint_perf_file(os.path.join(core_dir, fn), rep)
    assert "PERF701" not in rules(rep), rep.as_text()


# =====================================================================
# findings / report plumbing
# =====================================================================

def test_report_suppression_and_render():
    rep = Report(disabled=["DG102"])
    assert rep.add("DG102", Severity.ERROR, "suppressed") is None
    rep.add("DG103", Severity.ERROR, "kept", where="x", hint="fix it")
    assert rules(rep) == {"DG103"} and not rep.ok
    assert "fix it" in rep.as_text()
    assert '"rule": "DG103"' in rep.as_json()


# =====================================================================
# launch-time validation + CLI
# =====================================================================

def test_overlord_validate_rejects_bad_config(tmp_path):
    tree = tree4()
    sched = StaticSchedule({"a": 1.0})
    with pytest.raises(AnalysisError) as ei:
        Overlord({}, tree, sched,
                 OverlordConfig(strategy="not_a_strategy"))
    assert any(f.rule == "CFG303" for f in ei.value.report.errors)
    # validate=False opts out (legacy escape hatch)
    ov = Overlord({}, tree, sched,
                  OverlordConfig(strategy="not_a_strategy"),
                  validate=False)
    assert ov.analysis is None
    ov.runtime.shutdown()


def test_overlord_validate_accepts_good_config():
    ov = Overlord({}, tree4(), StaticSchedule({"a": 1.0}),
                  good_overlord_cfg())
    assert ov.analysis is not None and ov.analysis.ok
    ov.runtime.shutdown()


def test_validate_launch_matches_cli_rules():
    rep = validate_launch(OverlordConfig(strategy="nope"), tree4(),
                          n_sources=2)
    assert "CFG303" in rules(rep)


BAD_FIXTURE = """
import threading
from repro.configs.base import ModelConfig
from repro.core.actors import Actor
from repro.core.orchestrator import OverlordConfig

BAD_MODEL = ModelConfig(
    name="bad-fixture", family="dense", num_layers=2, d_model=100,
    num_heads=3, num_kv_heads=2, d_ff=64, vocab_size=0)

BAD_OVERLORD = OverlordConfig(strategy="does_not_exist", fill_factor=0.0)


class BadActor(Actor):
    def checkpoint_state(self):
        return {}

    def wait(self, peer):
        return peer.call("x", timeout=None)
"""


def _run_cli(args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, env={"PYTHONPATH": "src",
                                             "PATH": "/usr/bin:/bin",
                                             "JAX_PLATFORMS": "cpu"})


def test_cli_nonzero_on_seeded_bad_fixture(tmp_path):
    bad = tmp_path / "bad_fixture.py"
    bad.write_text(BAD_FIXTURE)
    proc = _run_cli([str(bad), "--format", "json"])
    assert proc.returncode == 1, proc.stderr
    import json
    out = json.loads(proc.stdout)
    got = {f["rule"] for f in out["findings"]}
    assert {"MDL401", "CFG303", "ACT504", "ACT505"} <= got


def test_cli_zero_on_shipped_surface():
    proc = _run_cli([])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout
    proc2 = _run_cli(["src/repro/configs"])
    assert proc2.returncode == 0, proc2.stdout + proc2.stderr


def test_cli_disable_suppresses_rule(tmp_path):
    bad = tmp_path / "only_ckpt.py"
    bad.write_text(textwrap.dedent("""
        from repro.core.actors import Actor

        class Half(Actor):
            def restore_state(self, s):
                pass
    """))
    assert lint_main([str(bad)]) == 1
    assert lint_main([str(bad), "--disable", "ACT505"]) == 0
