"""Property-based invariants of the telemetry plane.

Four laws the rest of the PR leans on:

  * span trees are well-nested — a randomly shaped program of nested
    ``with tracer.span(...)`` blocks reconstructs to exactly its own
    shape via ``canonical_spans``;
  * histogram quantiles are monotone in q for ANY observation stream;
  * counter merge is associative (integer increments are exact in
    float64, so equality is exact, not approximate);
  * under a random fault schedule, ``telemetry_report()``'s delivered-
    sample count reconciles with the DeliveryLedger's.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the dev extra "
                         "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.chaos import FaultInjector, FaultSchedule  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core import (  # noqa: E402
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost  # noqa: E402
from repro.data.sources import (  # noqa: E402
    coyo_like_specs, materialize_group,
)
from repro.telemetry import (  # noqa: E402
    Counter, Histogram, Tracer, canonical_spans,
)


# =====================================================================
# well-nested span trees
# =====================================================================

# a "program" is a forest: each node is a list of child programs
program = st.recursive(
    st.lists(st.nothing(), max_size=0),
    lambda kids: st.lists(kids, max_size=4),
    max_leaves=20)


def _run(tracer, forest, depth=0):
    for i, kids in enumerate(forest):
        with tracer.span(f"n{depth}.{i}"):
            _run(tracer, kids, depth + 1)


def _shape(forest, depth=0):
    return [{"name": f"n{depth}.{i}", "attrs": {},
             **({"children": _shape(kids, depth + 1)} if kids else {})}
            for i, kids in enumerate(forest)]


@given(program)
@settings(max_examples=80, deadline=None)
def test_span_forest_reconstructs_program_shape(forest):
    tr = Tracer()
    _run(tr, forest)
    assert canonical_spans(tr.finished()) == _shape(forest)


# =====================================================================
# histogram quantile monotonicity
# =====================================================================

@given(st.lists(st.floats(-1e9, 1e9, allow_nan=False), min_size=1,
                max_size=300),
       st.lists(st.floats(0.0, 1.0, allow_nan=False), min_size=2,
                max_size=20),
       st.integers(1, 64), st.integers(0, 2**32 - 1))
@settings(max_examples=80, deadline=None)
def test_histogram_quantiles_monotone(values, qs, capacity, seed):
    h = Histogram(capacity=capacity, seed=seed)
    for v in values:
        h.observe(v)
    got = h.quantiles(sorted(qs))
    assert got == sorted(got)
    assert min(values) <= got[0] and got[-1] <= max(values)
    assert h.count == len(values)


# =====================================================================
# counter merge associativity
# =====================================================================

@given(st.lists(st.integers(0, 2**40), min_size=3, max_size=3))
@settings(max_examples=100, deadline=None)
def test_counter_merge_associative(vals):
    a, b, c = (Counter(float(v)) for v in vals)
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.value == right.value == float(sum(vals))


# =====================================================================
# ledger reconciliation under random fault schedules
# =====================================================================

N_SOURCES = 2
STEPS = 10


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("telemetry_prop_sources")
    return materialize_group(coyo_like_specs(N_SOURCES), str(root))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_delivery_reconciles_under_random_faults(source_paths, seed):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0
                            for i in range(N_SOURCES)})
    cfg = OverlordConfig(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance", shadows=False, ledger=True,
        seed=seed % 1000,
        strategy_params=dict(costfn=backbone_cost(get_config("qwen3-8b")),
                             broadcast=()))
    schedule = FaultSchedule.generate(
        seed, STEPS, rate=0.2, warmup=2,
        kinds=("io_error", "corrupt", "slow"),
        ensure=("io_error", "corrupt"))
    ov = Overlord(source_paths, tree, sched, cfg).start()
    injector = FaultInjector(ov, schedule)
    try:
        for step in range(STEPS):
            injector.on_step(step)
            for r in range(ov.tree.world):
                ov.get_batch(step, r, timeout=30)
            ov.step_done(step)
        rep = ov.telemetry_report()
        ledger = ov.ledger.verify(strict=False)
        assert rep["delivery"]["delivered_samples"] == ledger["delivered"]
        for (step, kind, target, _params) in injector.timeline():
            assert ov.telemetry.tracer.find(
                "chaos.inject", fault=kind, step=step,
                target=str(target)), f"unstamped fault {kind}@{step}"
    finally:
        injector.uninstall()
        ov.shutdown()
