"""Mixture schedules + two-phase autoscaling."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the dev extra "
                         "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.autoscale import (
    PartitionLimits, SourceProfile, auto_partition,
)
from repro.core.mixing import (
    AdaptiveSchedule, CurriculumSchedule, StagedSchedule, StaticSchedule,
    sample_counts,
)

w_strategy = st.dictionaries(
    st.sampled_from([f"s{i}" for i in range(6)]),
    st.floats(0.0, 100.0, allow_nan=False), min_size=1, max_size=6)


@given(w_strategy, st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_static_schedule_normalized(ratios, step):
    w = StaticSchedule(ratios).weights(step)
    assert abs(sum(w.values()) - 1.0) < 1e-9
    assert all(v >= 0 for v in w.values())


def test_staged_schedule_transitions():
    s = StagedSchedule([(10, {"a": 1.0}), (20, {"b": 1.0})])
    assert s.weights(5)["a"] == 1.0
    assert s.weights(15)["b"] == 1.0
    assert s.weights(99)["b"] == 1.0


def test_curriculum_monotone_ramp():
    s = CurriculumSchedule(easy={"e": 1.0}, hard={"h": 1.0}, ramp_steps=100)
    hw = [s.weights(t).get("h", 0.0) for t in range(0, 101, 10)]
    assert hw == sorted(hw)
    assert hw[0] == 0.0 and abs(hw[-1] - 1.0) < 1e-9


def test_adaptive_boosts_lossy_source():
    s = AdaptiveSchedule({"a": 0.5, "b": 0.5}, temperature=0.5)
    for t in range(20):
        s.observe(t, {"per_source_loss": {"a": 4.0, "b": 1.0}})
    w = s.weights(20)
    assert w["a"] > w["b"]


@given(w_strategy, st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_sample_counts_sum(ratios, total):
    rng = np.random.default_rng(0)
    counts = sample_counts(ratios, total, rng)
    assert sum(counts.values()) == total
    assert all(v >= 0 for v in counts.values())


# ------------------------------------------------------- auto-partition
def _profiles(n, seed=0):
    rng = np.random.default_rng(seed)
    return [SourceProfile(f"s{i}", float(rng.lognormal(2, 1)),
                          int(rng.integers(1 << 20, 1 << 24)))
            for i in range(n)]


@given(st.integers(1, 40), st.integers(4, 64), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_auto_partition_respects_bounds(n_sources, total_workers, w_actor):
    limits = PartitionLimits(total_workers=total_workers, w_actor=w_actor,
                             w_src=16)
    cfgs = auto_partition(_profiles(n_sources), limits)
    assert cfgs, "every source must get at least one loader"
    by_source = {}
    for c in cfgs:
        by_source.setdefault(c.source, []).append(c)
        assert 1 <= c.workers <= w_actor
    assert len(by_source) == n_sources
    for src, lst in by_source.items():
        # shards are a consistent (i, n) partition
        n = lst[0].shard_count
        assert sorted(c.shard_index for c in lst) == list(range(n))
        assert sum(c.workers for c in lst) <= limits.w_src * max(n, 1)


def test_expensive_sources_get_more_workers():
    profiles = [SourceProfile("cheap", 1.0, 1 << 20),
                SourceProfile("mid", 10.0, 1 << 20),
                SourceProfile("pricey", 300.0, 1 << 20)]
    cfgs = auto_partition(profiles, PartitionLimits(
        total_workers=32, w_actor=4, cluster_size=1))
    per = {}
    for c in cfgs:
        per[c.source] = per.get(c.source, 0) + c.workers
    assert per["pricey"] >= per["mid"] >= per["cheap"]


def test_memory_budget_forces_sharding():
    big = SourceProfile("big", 5.0, 1 << 30)
    cfgs = auto_partition([big], PartitionLimits(
        total_workers=8, memory_budget=1 << 28))
    assert cfgs[0].shard_count >= 2
