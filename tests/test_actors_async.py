"""``call_async`` / ``cast`` / ``FanOut`` under injected faults.

The pipelined planner path (docs/PERFORMANCE.md) rides entirely on these
primitives, so their failure semantics are pinned here: futures resolve
with results or the method's exception, kill() fails in-flight futures
with ActorDied instead of wedging callers, and a FanOut wave preserves
per-call RetryPolicy behavior while never raising from gather().
"""
import time
from concurrent.futures import Future

import pytest

from repro.core.actors import ActorDied, ActorRuntime, Actor, FanOut
from repro.core.resilience import RetryPolicy, TransientIOError


class Worker(Actor):
    def __init__(self, fail_first: int = 0):
        self.seen = []
        self.fail_budget = fail_first

    def echo(self, x):
        return x

    def boom(self):
        raise ValueError("boom")

    def flaky(self):
        if self.fail_budget > 0:
            self.fail_budget -= 1
            raise TransientIOError("injected hiccup")
        return f"{self.name}:ok"

    def slow(self, seconds):
        time.sleep(seconds)
        return "slow-done"

    def note(self, x):
        self.seen.append(x)

    def notes(self):
        return list(self.seen)


@pytest.fixture
def runtime():
    rt = ActorRuntime(heartbeat_interval=0.02)
    yield rt
    rt.shutdown()


# ------------------------------------------------------------ call_async
def test_call_async_returns_future_with_result(runtime):
    h = runtime.spawn("w", Worker())
    fut = h.call_async("echo", 41)
    assert isinstance(fut, Future)
    assert fut.result(timeout=5) == 41


def test_call_async_propagates_method_exception(runtime):
    h = runtime.spawn("w", Worker())
    fut = h.call_async("boom")
    with pytest.raises(ValueError, match="boom"):
        fut.result(timeout=5)


def test_call_async_on_dead_handle_raises_at_submit(runtime):
    h = runtime.spawn("w", Worker())
    h.kill()
    with pytest.raises(ActorDied):
        h.call_async("echo", 1)


def test_kill_fails_pending_async_future(runtime):
    h = runtime.spawn("w", Worker())
    # occupy the mailbox thread, then queue a future behind it
    h.cast("slow", 0.3)
    fut = h.call_async("echo", "never")
    h.kill()
    with pytest.raises(ActorDied):
        fut.result(timeout=5)


def test_async_calls_overlap_across_actors(runtime):
    """The point of the fan-out: N slow calls cost ~1 latency, not N."""
    handles = [runtime.spawn(f"w{i}", Worker()) for i in range(4)]
    t0 = time.perf_counter()
    futs = [h.call_async("slow", 0.1) for h in handles]
    assert all(f.result(timeout=5) == "slow-done" for f in futs)
    elapsed = time.perf_counter() - t0
    assert elapsed < 0.3, f"async wave serialized: {elapsed:.2f}s"


# ------------------------------------------------------------------ cast
def test_cast_applies_side_effect_in_order(runtime):
    h = runtime.spawn("w", Worker())
    for x in ("a", "b", "c"):
        h.cast("note", x)
    assert h.call("notes", timeout=5) == ["a", "b", "c"]


def test_cast_on_dead_handle_raises(runtime):
    h = runtime.spawn("w", Worker())
    h.kill()
    with pytest.raises(ActorDied):
        h.cast("note", "x")


def test_cast_exception_does_not_kill_actor(runtime):
    h = runtime.spawn("w", Worker())
    h.cast("boom")                       # logged, no future to fail
    assert h.call("echo", "still-alive", timeout=5) == "still-alive"
    assert h.alive


# ---------------------------------------------------------------- FanOut
def test_fanout_gathers_all_results(runtime):
    handles = {f"w{i}": runtime.spawn(f"w{i}", Worker()) for i in range(3)}
    fo = FanOut()
    for name, h in handles.items():
        fo.submit(name, h, "echo", name.upper())
    results = fo.gather()
    assert results == {"w0": "W0", "w1": "W1", "w2": "W2"}
    assert fo.failures == {}


def test_fanout_retries_transient_fault_to_success(runtime):
    h = runtime.spawn("w", Worker(fail_first=2))
    fo = FanOut()
    fo.submit("w", h, "flaky", timeout=5,
              retry=RetryPolicy(max_attempts=3, base_delay_s=0.001,
                                max_delay_s=0.01))
    assert fo.gather() == {"w": "w:ok"}
    assert fo.failures == {}


def test_fanout_exhausted_retries_land_in_failures(runtime):
    h = runtime.spawn("w", Worker(fail_first=10))
    fo = FanOut()
    fo.submit("w", h, "flaky", timeout=5,
              retry=RetryPolicy(max_attempts=2, base_delay_s=0.001,
                                max_delay_s=0.01))
    results = fo.gather()          # never raises
    assert results == {}
    assert isinstance(fo.failures["w"], TransientIOError)


def test_fanout_no_retry_policy_fails_immediately(runtime):
    h = runtime.spawn("w", Worker(fail_first=1))
    fo = FanOut()
    fo.submit("w", h, "flaky", timeout=5)
    assert fo.gather() == {}
    assert isinstance(fo.failures["w"], TransientIOError)


def test_fanout_partial_results_with_dead_handle(runtime):
    alive = runtime.spawn("alive", Worker())
    dead = runtime.spawn("dead", Worker())
    dead.kill()
    fo = FanOut()
    fo.submit("alive", alive, "echo", 7)
    fo.submit("dead", dead, "echo", 8)
    results = fo.gather()
    assert results == {"alive": 7}
    assert isinstance(fo.failures["dead"], ActorDied)


def test_fanout_actordied_is_terminal_even_with_retry(runtime):
    """A dead handle stays dead under the same object: FanOut must not
    spin its retry budget on it (chasing respawns is call_with_retry's
    job)."""
    h = runtime.spawn("w", Worker())
    h.cast("slow", 0.3)
    fo = FanOut()
    fo.submit("w", h, "echo", 9, timeout=5,
              retry=RetryPolicy(max_attempts=5, base_delay_s=0.001,
                                max_delay_s=0.01))
    h.kill()
    t0 = time.perf_counter()
    assert fo.gather() == {}
    assert isinstance(fo.failures["w"], ActorDied)
    assert time.perf_counter() - t0 < 1.0


def test_fanout_metrics(runtime):
    from repro.telemetry import Telemetry
    tel = Telemetry(enabled=True)
    rt = ActorRuntime(heartbeat_interval=0.02, telemetry=tel)
    try:
        h = rt.spawn("w", Worker())
        fo = FanOut(telemetry=tel)
        fo.submit("w", h, "echo", 1)
        fo.gather()
        h.cast("note", "x")
        assert tel.registry.counter_total("actor_async_calls_total") >= 1
        assert tel.registry.counter_total("actor_casts_total") >= 1
        names = {s.name for s in tel.tracer.finished()}
        assert "actor.fanout" in names
    finally:
        rt.shutdown()
