"""Actor runtime semantics + columnar storage roundtrip/accounting."""
import os
import time

import numpy as np
import pytest

from repro.core.actors import Actor, ActorDied, ActorRuntime
from repro.data import storage
from repro.data.sources import SourceSpec, materialize_source


class Counter(Actor):
    def __init__(self):
        self.n = 0

    def bump(self, k=1):
        self.n += k
        return self.n

    def boom(self):
        raise ValueError("boom")

    def memory_bytes(self):
        return 1234


@pytest.fixture
def runtime():
    rt = ActorRuntime(heartbeat_interval=0.01)
    yield rt
    rt.shutdown()


def test_call_cast_and_exceptions(runtime):
    h = runtime.spawn("c", Counter())
    assert h.call("bump") == 1
    h.cast("bump", 5)
    assert h.call("bump") == 7      # mailbox is ordered
    with pytest.raises(ValueError):
        h.call("boom")
    assert h.call("memory_bytes") == 1234


def test_kill_fails_pending_futures_and_fires_supervision(runtime):
    h = runtime.spawn("c", Counter())
    failures = []
    runtime.on_failure(lambda n, hh: failures.append(n))
    fut = h.call_async("bump")
    fut.result(timeout=1)
    h.kill()
    deadline = time.time() + 2
    while h.alive and time.time() < deadline:
        time.sleep(0.01)
    assert not h.alive
    with pytest.raises(ActorDied):
        h.call("bump")
    deadline = time.time() + 2
    while not failures and time.time() < deadline:
        time.sleep(0.01)
    assert failures == ["c"]
    # respawn under the same name works after death
    h2 = runtime.spawn("c", Counter())
    assert h2.call("bump") == 1


def test_reassign(runtime):
    h = runtime.spawn("a", Counter())
    runtime.reassign("a", "b")
    assert runtime.get("b") is h
    with pytest.raises(KeyError):
        runtime.get("a")


# ------------------------------------------------------------- storage
def test_storage_roundtrip(tmp_path):
    recs = [{"sample_id": f"s{i}", "text_tokens": i + 1, "image_tokens": 0,
             "modality": "text", "transform_cost": 1.0, "payload": b"xy",
             "seed": i} for i in range(100)]
    path = str(tmp_path / "t.colstore")
    footer = storage.write_source(path, recs, row_group_rows=16)
    assert footer["num_rows"] == 100
    with storage.SourceReader(path) as r:
        assert r.num_rows == 100
        out = r.read(5)
        assert [o["sample_id"] for o in out] == [f"s{i}" for i in range(5)]
        # wrap-around epoch semantics
        r.seek(98)
        out = r.read(4)
        assert [o["sample_id"] for o in out] == ["s98", "s99", "s0", "s1"]
        assert r.access_state_bytes > 8192  # socket + footer + buffer


def test_storage_sharding_partitions_rows(tmp_path):
    recs = [{"sample_id": f"s{i}", "text_tokens": 1, "image_tokens": 0,
             "modality": "text", "transform_cost": 1.0, "payload": b"",
             "seed": i} for i in range(64)]
    path = str(tmp_path / "t.colstore")
    storage.write_source(path, recs, row_group_rows=8)
    seen = set()
    total = 0
    for i in range(2):
        with storage.SourceReader(path, shard=(i, 2)) as r:
            total += r.num_rows
            for rec in r.read(r.num_rows):
                seen.add(rec["sample_id"])
    assert total == 64 and len(seen) == 64


def test_open_reader_accounting(tmp_path):
    spec = SourceSpec("acc_test", "text", n_samples=32)
    path = materialize_source(spec, str(tmp_path))
    base = storage.open_reader_count()
    r1 = storage.SourceReader(path)
    r2 = storage.SourceReader(path)
    assert storage.open_reader_count() == base + 2
    assert storage.open_access_state_bytes() >= r1.access_state_bytes
    r1.close(), r2.close()
    assert storage.open_reader_count() == base
