"""Optimizer correctness + loss-goes-down integration."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import make_lm_batch
from repro.train.optimizer import (
    AdamWConfig, adamw_update, init_adamw, lr_schedule,
)
from repro.train.train_step import init_train_state, make_train_step


def test_adamw_matches_reference_formulas():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10**9,
                      weight_decay=0.0, clip_norm=1e9, min_lr_ratio=1.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.25])}
    st = init_adamw(p)
    new_p, st1, _ = adamw_update(cfg, g, st, p)
    # step 1: mhat = g, vhat = g^2  ->  update = lr * g/(|g|+eps)
    exp = np.array([1.0, -2.0]) - 1e-2 * np.array([0.5, 0.25]) / (
        np.abs([0.5, 0.25]) + cfg.eps)
    np.testing.assert_allclose(np.asarray(new_p["w"]), exp, rtol=1e-5)
    assert int(st1.step) == 1


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 1e6)}
    _, st1, metrics = adamw_update(cfg, g, init_adamw(p), p)
    assert float(metrics["grad_norm"]) > 1e6
    # clipped first moment: |m| <= (1-b1) * clip_norm
    assert float(jnp.max(jnp.abs(st1.mu["w"]))) <= 0.11


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(t))) for t in
           (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


def test_loss_decreases_tiny_model():
    from repro.configs.qwen3_8b import reduced
    from repro.models.model_zoo import build_model
    cfg = reduced().replace(num_layers=2)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, AdamWConfig(
        peak_lr=5e-3, warmup_steps=5, total_steps=100)))
    batch = make_lm_batch(cfg, 4, 64, seed=3)
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)   # memorize one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses
    assert np.isfinite(losses).all()


def test_moe_train_step_routes_and_learns():
    from repro.configs.qwen3_moe_30b_a3b import reduced
    from repro.models.model_zoo import build_model
    cfg = reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, AdamWConfig(peak_lr=5e-3,
                                                      warmup_steps=2)))
    batch = make_lm_batch(cfg, 4, 64, seed=4)
    losses, auxes = [], []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        auxes.append(float(m["aux_loss"]))
    assert losses[-1] < losses[0]
    # aux loss stays near 1.0-ish (balanced routing) and finite
    assert all(np.isfinite(a) and a < 16.0 for a in auxes)
