"""Tests for the balancing core (the paper's scheduler).

Two layers: (1) randomized equivalence of the vectorized implementations
against the retained ``_*_reference`` originals — these run everywhere
(stdlib ``random`` only); (2) hypothesis property tests, defined only
when the dev extra is installed.
"""
import random

import numpy as np
import pytest

from repro.core.balance import (
    METHODS, REFERENCE_METHODS, balance_items, bin_loads, greedy_binpack,
    imbalance, karmarkar_karp, multi_greedy_binpack,
)


# ----------------------------------------------------- vectorized == reference
def _random_costs(rng, n):
    if rng.random() < 0.3:
        # heavy ties: stresses stable-sort / heap tie-breaking
        return [rng.choice([1.0, 2.0, 4.0, 4.0]) for _ in range(n)]
    return [rng.random() * 10.0 for _ in range(n)]


@pytest.mark.parametrize("method", ["greedy_binpack", "karmarkar_karp"])
def test_vectorized_matches_reference_assignments(method):
    rng = random.Random(1234)
    for _ in range(200):
        n = rng.randrange(0, 64)
        k = rng.randrange(1, 9)
        costs = _random_costs(rng, n)
        got = METHODS[method](costs, k)
        want = REFERENCE_METHODS[method](costs, k)
        # item-for-item identical, hence identical imbalance too
        assert got == want, (method, n, k, costs)


def test_multi_greedy_matches_reference_assignments():
    rng = random.Random(99)
    for _ in range(200):
        n = rng.randrange(0, 48)
        k = rng.randrange(1, 9)
        d = rng.randrange(1, 4)
        vecs = [[rng.random() * 5.0 for _ in range(d)] for _ in range(n)]
        got = multi_greedy_binpack(vecs, k)
        want = REFERENCE_METHODS["multi_greedy_binpack"](vecs, k)
        assert got == want, (n, k, d, vecs)


def test_vectorized_imbalance_never_worse():
    """Belt-and-braces for the acceptance bar: even if assignments ever
    diverge, the vectorized straggler must be same-or-better."""
    rng = random.Random(7)
    for _ in range(100):
        n = rng.randrange(1, 64)
        k = rng.randrange(1, 9)
        costs = _random_costs(rng, n)
        for method in ("greedy_binpack", "karmarkar_karp"):
            got = imbalance(bin_loads(
                costs, METHODS[method](costs, k), k))
            want = imbalance(bin_loads(
                costs, REFERENCE_METHODS[method](costs, k), k))
            assert got <= want + 1e-9


def test_empty_and_single_item_edges():
    assert greedy_binpack([], 3) == []
    assert karmarkar_karp([], 3) == []
    assert multi_greedy_binpack([], 3) == []
    assert greedy_binpack([5.0], 3) == REFERENCE_METHODS[
        "greedy_binpack"]([5.0], 3)
    assert karmarkar_karp([5.0], 3) == REFERENCE_METHODS[
        "karmarkar_karp"]([5.0], 3)
    with pytest.raises(ValueError):
        greedy_binpack([1.0], 0)
    with pytest.raises(ValueError):
        karmarkar_karp([1.0], 0)
    with pytest.raises(ValueError):
        multi_greedy_binpack([[1.0]], 0)


def test_balance_reduces_imbalance_on_skewed_data():
    rng = np.random.default_rng(0)
    costs = np.square(rng.lognormal(3.0, 1.2, 512)).tolist()
    n = 16
    rr = [i % n for i in range(len(costs))]
    base = imbalance(bin_loads(costs, rr, n))
    bal_loads = bin_loads(costs, greedy_binpack(costs, n), n)
    assert imbalance(bal_loads) < base
    # LPT lands within 4/3 of the theoretical lower bound even under the
    # heavy-tailed quadratic-cost skew (a single giant doc can dominate)
    lower = max(max(costs), sum(costs) / n)
    assert max(bal_loads) <= 4.0 / 3.0 * lower


def test_unknown_method_raises():
    with pytest.raises(ValueError):
        balance_items([1.0], 2, "nope")


# ------------------------------------------------------- property tests
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # pragma: no cover - dev extra absent
    given = None

if given is not None:
    costs_strategy = st.lists(
        st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                  allow_infinity=False),
        min_size=1, max_size=200)

    @given(costs_strategy, st.integers(1, 16))
    @settings(max_examples=100, deadline=None)
    def test_greedy_assignment_valid(costs, n_bins):
        assign = greedy_binpack(costs, n_bins)
        assert len(assign) == len(costs)
        assert all(0 <= a < n_bins for a in assign)
        # conservation: every item assigned exactly once
        assert sum(bin_loads(costs, assign, n_bins)) == pytest.approx(
            sum(costs), rel=1e-6, abs=1e-6)

    @given(costs_strategy, st.integers(1, 8))
    @settings(max_examples=100, deadline=None)
    def test_greedy_within_4_3_of_round_robin(costs, n_bins):
        """LPT is 4/3-of-OPT, hence within 4/3 of ANY assignment's max
        load (instance-wise dominance over round-robin does not hold in
        general)."""
        assign = greedy_binpack(costs, n_bins)
        rr = [i % n_bins for i in range(len(costs))]
        g = max(bin_loads(costs, assign, n_bins))
        r = max(bin_loads(costs, rr, n_bins))
        assert g <= 4.0 / 3.0 * r + 1e-6

    @given(costs_strategy, st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_greedy_within_4_3_of_lower_bound(costs, n_bins):
        """LPT is a 4/3-approx: max load <= 4/3 * OPT + max item slack."""
        assign = greedy_binpack(costs, n_bins)
        got = max(bin_loads(costs, assign, n_bins))
        lower = max(sum(costs) / n_bins, max(costs) if costs else 0.0)
        assert got <= 4.0 / 3.0 * lower + 1e-6

    @given(st.lists(st.floats(min_value=0.1, max_value=1e4), min_size=2,
                    max_size=40), st.integers(2, 6))
    @settings(max_examples=50, deadline=None)
    def test_karmarkar_karp_valid_and_competitive(costs, n_bins):
        kk = karmarkar_karp(costs, n_bins)
        assert len(kk) == len(costs)
        assert all(0 <= a < n_bins for a in kk)
        assert sum(bin_loads(costs, kk, n_bins)) == pytest.approx(
            sum(costs))
        # KK should not be wildly worse than greedy
        kk_max = max(bin_loads(costs, kk, n_bins))
        g_max = max(bin_loads(costs, greedy_binpack(costs, n_bins),
                              n_bins))
        assert kk_max <= 2.0 * g_max + 1e-6

    @given(st.lists(st.tuples(st.floats(0, 1e4), st.floats(0, 1e4)),
                    min_size=1, max_size=60), st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_multi_greedy_valid(vectors, n_bins):
        assign = multi_greedy_binpack(vectors, n_bins)
        assert len(assign) == len(vectors)
        assert all(0 <= a < n_bins for a in assign)
else:
    @pytest.mark.skip(reason="property tests need the dev extra "
                             "(pip install -e .[dev])")
    def test_property_suite_needs_hypothesis():
        pass
