"""Chaos soak (docs/FAULT_TOLERANCE.md): a seeded fault schedule runs
against a live Overlord for 60 steps; delivery must never raise, the
DeliveryLedger must prove zero loss / zero duplication, corrupted
samples must land in the dead-letter queue with source attribution, and
the same seed must reproduce the identical fault timeline.

``CHAOS_SEED`` selects the schedule (CI soaks a different seed than the
local default) — any seed is required to pass.
"""
import os
import time

import pytest

from repro.chaos import FaultInjector, FaultSchedule
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "1234"))
STEPS = 60
N_SOURCES = 3


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("chaos_sources")
    return materialize_group(coyo_like_specs(N_SOURCES), str(root))


def mk(source_paths, start=True, **kw):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0 for i in range(N_SOURCES)})
    defaults = dict(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance", shadows=True, ledger=True,
        loader_ckpt_every=4,
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()))
    defaults.update(kw)
    ov = Overlord(source_paths, tree, sched, OverlordConfig(**defaults))
    return ov.start() if start else ov


def run_soak(source_paths, schedule, steps=STEPS):
    ov = mk(source_paths)
    injector = FaultInjector(ov, schedule)
    try:
        for step in range(steps):
            injector.on_step(step)
            for r in range(ov.tree.world):
                v = ov.get_batch(step, r, timeout=30)  # must never raise
                assert v["role"] in ("data", "metadata", "none")
            ov.step_done(step)
        time.sleep(0.3)   # let in-flight recoveries settle
        ov.step_done(steps - 1)   # refresh quarantine mirror post-settle
        summary = ov.ledger.verify(strict=True)
        return {
            "timeline": injector.timeline(),
            "errors": list(injector.errors),
            "summary": summary,
            "dlq": ov.dlq.counts_by_source(),
            "report": ov.resilience_report(),
        }
    finally:
        injector.uninstall()
        ov.shutdown()


def test_generated_schedule_covers_required_kinds():
    sched = FaultSchedule.generate(CHAOS_SEED, STEPS)
    assert {"crash_loader", "corrupt", "io_error"} <= sched.kinds()
    assert len(sched) >= 3
    # deterministic generation: same seed, same timeline
    again = FaultSchedule.generate(CHAOS_SEED, STEPS)
    assert sched == again
    assert sched.signature() == again.signature()


def test_schedule_json_roundtrip(tmp_path):
    sched = FaultSchedule.generate(CHAOS_SEED, STEPS)
    path = str(tmp_path / "schedule.json")
    sched.save(path)
    loaded = FaultSchedule.load(path)
    assert loaded == sched
    assert loaded.seed == CHAOS_SEED


def test_chaos_soak_no_loss_no_duplicates(source_paths):
    sched = FaultSchedule.generate(CHAOS_SEED, STEPS)
    out = run_soak(source_paths, sched)

    # ledger invariants: verify(strict=True) above already raises on
    # violation; assert the headline numbers anyway
    s = out["summary"]
    assert s["ok"]
    assert s["lost"] == []
    assert s["duplicates"] == {}
    assert s["rank_skew"] == []
    assert s["quarantine_leaks"] == []
    assert s["delivered"] > 0

    # >= 3 distinct fault kinds actually fired, incl. the required ones
    fired_kinds = {k for (_, k, _, _) in out["timeline"]}
    assert len(fired_kinds) >= 3
    assert {"crash_loader", "corrupt", "io_error"} <= fired_kinds

    # corrupted samples were quarantined with source attribution
    assert sum(out["dlq"].values()) > 0
    assert set(out["dlq"]) <= {f"coyo_{i:03d}" for i in range(N_SOURCES)}

    # the hardened surfaces saw the faults they absorb
    report = out["report"]
    assert sum(h["read_failures"] for h in report["loaders"].values()) >= 0
    assert report["dlq"]["total"] == sum(out["dlq"].values())


def run_process_death_soak(source_paths, ckpt_dir, seed,
                           steps=STEPS, deaths=3):
    """Drive the job through ``deaths`` whole-process crash/resume cycles
    against one on-disk checkpoint root; return the final ledger verdict
    (the ledger itself survives via the manifest)."""
    sched = FaultSchedule.process_death_soak(seed, steps, deaths=deaths)
    kw = dict(checkpoint_dir=ckpt_dir, loader_ckpt_every=4)
    injector = FaultInjector(
        mk(source_paths, **kw), sched,
        resume_factory=lambda: mk(source_paths, start=False, **kw).resume())
    try:
        for step in range(steps):
            injector.on_step(step)
            ov = injector.ov          # swapped by process_death events
            for r in range(ov.tree.world):
                v = ov.get_batch(step, r, timeout=60)  # must never raise
                assert v["role"] in ("data", "metadata", "none")
            ov.step_done(step)
        return {
            "timeline": injector.timeline(),
            "errors": list(injector.errors),
            "resumes": list(injector.resumes),
            "summary": injector.ov.ledger.verify(strict=True),
            "store": injector.ov.store.stats(),
        }
    finally:
        injector.uninstall()
        injector.ov.shutdown()


def test_process_death_soak_exactly_once(source_paths, tmp_path):
    """The durable-recovery acceptance soak: >=3 whole-runtime deaths
    mid-run, each resumed from the on-disk manifest, with the persisted
    DeliveryLedger proving zero loss and zero duplication end to end."""
    out = run_process_death_soak(
        source_paths, str(tmp_path / "pd_ckpt"), CHAOS_SEED)

    deaths = [e for e in out["timeline"] if e[1] == "process_death"]
    assert len(deaths) >= 3
    assert len(out["resumes"]) == len(deaths)
    assert out["errors"] == []

    # every resume found a real epoch (never a cold start) and replayed
    # a bounded window; fence tokens are strictly increasing
    tokens = []
    for r in out["resumes"]:
        rep = r["report"]
        assert rep is not None and not rep["cold_start"]
        assert rep["epoch"] >= 1
        assert 0 <= rep["replayed_steps"] <= 8
        tokens.append(rep["fence_token"])
    assert tokens == sorted(tokens) and len(set(tokens)) == len(tokens)

    # the headline: exactly-once delivery across crash/resume cycles
    s = out["summary"]
    assert s["ok"]
    assert s["lost"] == []
    assert s["duplicates"] == {}
    assert s["rank_skew"] == []
    assert s["delivered"] > 0

    # manifests committed throughout; nothing fenced or corrupt mid-soak
    assert out["store"]["manifests_committed"] > 0
    assert out["store"]["fenced_writes"] == 0


def test_process_death_schedule_requires_resume_factory(source_paths):
    sched = FaultSchedule.process_death_soak(CHAOS_SEED, STEPS)
    ov = mk(source_paths, start=False)
    with pytest.raises(ValueError, match="resume_factory"):
        FaultInjector(ov, sched, install_storage_hook=False)


def test_process_death_schedule_is_deterministic():
    a = FaultSchedule.process_death_soak(CHAOS_SEED, STEPS, deaths=3)
    b = FaultSchedule.process_death_soak(CHAOS_SEED, STEPS, deaths=3)
    assert a == b
    kinds = a.kinds()
    assert "process_death" in kinds
    # latency-only noise: no data-perturbing kinds in this soak
    assert not kinds & {"io_error", "corrupt", "crash_loader",
                        "crash_planner"}
    assert sum(1 for ev in a.events if ev.kind == "process_death") == 3


def test_same_seed_reproduces_identical_timeline(source_paths):
    sched = FaultSchedule.generate(CHAOS_SEED, STEPS)
    first = run_soak(source_paths, sched)
    second = run_soak(source_paths, sched)
    assert first["timeline"] == second["timeline"]
    assert len(first["timeline"]) == len(sched)
    # both runs stayed correct, not just identical
    assert first["summary"]["ok"] and second["summary"]["ok"]
