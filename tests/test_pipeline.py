"""Pipelined planning path (docs/PERFORMANCE.md): plan-ahead prefetch,
fan-out collect over merged ``snapshot`` RPCs, batched ``ingest``, and
the recovery semantics that keep all of it exactly-once.

Live tests run the real Overlord (threads and all); determinism-critical
behaviors (degraded re-mix, restore invalidation) drive the same plane
synchronously through a handle stand-in, like the golden-trace test.
"""
import time
from concurrent.futures import Future

import pytest

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.core.constructor import DataConstructor
from repro.core.planner import Planner
from repro.core.resilience import CircuitBreaker
from repro.core.source_loader import SourceLoader
from repro.core.strategies import STRATEGIES
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group
from repro.telemetry import Telemetry


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("pipeline_sources")
    return materialize_group(coyo_like_specs(3), str(root))


def mk(source_paths, **kw):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0 for i in range(3)})
    defaults = dict(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance",
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()))
    defaults.update(kw)
    return Overlord(source_paths, tree, sched,
                    OverlordConfig(**defaults)).start()


def run_steps(ov, lo, hi, compute_s=0.005, timeout=30.0):
    for step in range(lo, hi):
        for r in range(ov.tree.world):
            v = ov.get_batch(step, r, timeout=timeout)
            assert v["role"] in ("data", "metadata", "none")
        ov.step_done(step)
        time.sleep(compute_s)   # the window the planner runs ahead in


# --------------------------------------------------------- live pipeline
def test_plan_ahead_runs_ahead_of_consumer(source_paths):
    """With plan_ahead=2 the planner frontier stays beyond the newest
    consumed step, the prefetch-depth gauge is exported, and steps are
    fully planned BEFORE the trainer asks for them."""
    ov = mk(source_paths, plan_ahead=2, fanout_rpc=True, shadows=False)
    try:
        last = 7
        run_steps(ov, 0, last + 1)
        time.sleep(0.5)   # drain the final advance_to cast
        planned = ov.planner.call("planned_through", timeout=10)
        assert planned > last, \
            f"frontier {planned} not ahead of consumer {last}"
        depth = ov.telemetry.registry.gauge_value("planner_prefetch_depth")
        assert depth == depth, "planner_prefetch_depth gauge missing"
        spans = ov.telemetry.tracer.finished()
        names = {s.name for s in spans}
        assert "planner.pipeline" in names

        # at least one prefetched step finished planning strictly before
        # any rank fetched it — planning left the critical path
        plan_end = {}
        for s in spans:
            if s.name == "planner.plan_step" and s.end is not None:
                plan_end[s.attrs.get("step")] = s.end
        fetch_start = {}
        for s in spans:
            if s.name == "overlord.get_batch":
                st = s.attrs.get("step")
                fetch_start[st] = min(fetch_start.get(st, s.start), s.start)
        ahead = [t for t in fetch_start
                 if t in plan_end and plan_end[t] <= fetch_start[t]]
        assert ahead, "no step was planned before its first fetch"
    finally:
        ov.shutdown()


def test_serial_baseline_still_works(source_paths):
    """plan_ahead=0 + fanout_rpc=False is the measured pre-pipeline
    baseline (benchmarks/orchestration.run_pipeline) — it must keep
    delivering, just demand-driven."""
    ov = mk(source_paths, plan_ahead=0, fanout_rpc=False, shadows=False)
    try:
        run_steps(ov, 0, 3, compute_s=0.0)
        assert ov.planner.call("planned_through", timeout=10) >= 2
    finally:
        ov.shutdown()


def test_ingest_first_plan_wins(source_paths):
    """A replanning planner must not overwrite an assembled step: the
    batched ingest RPC returns False for a step a client may have
    consumed, exactly like expect() did."""
    ov = mk(source_paths, plan_ahead=2, shadows=False)
    try:
        run_steps(ov, 0, 2)
        for b, h in ov.constructors.items():
            assert h.call("ingest", 0, {}, 1, timeout=10) is False
    finally:
        ov.shutdown()


def test_replay_after_recovery_keeps_ledger_clean(source_paths):
    """Planner crash mid-pipeline: prefetched-but-lost plans are replanned,
    already-assembled steps win, and the delivery ledger still proves
    no-loss / no-duplication across the recovery."""
    ov = mk(source_paths, plan_ahead=2, fanout_rpc=True, shadows=False,
            ledger=True, planner_ckpt_every=1)
    try:
        run_steps(ov, 0, 4)
        ov.inject_planner_failure()
        time.sleep(0.5)
        run_steps(ov, 4, 8)
        assert any(r["actor"] == "planner" for r in ov.recovery_log)
        report = ov.ledger.verify(strict=True)   # raises on loss/dup
        assert report["delivered"] > 0
    finally:
        ov.shutdown()


# ------------------------------------------------- synchronous unit path
class _SyncHandle:
    """Dispatch on the caller's thread (same stand-in as the golden-trace
    test) so re-mix and restore behavior is deterministic."""

    alive = True

    def __init__(self, actor):
        self._actor = actor
        self.name = getattr(actor, "name", type(actor).__name__)

    def call(self, method, *args, timeout=None, retry=None, **kwargs):
        return getattr(self._actor, method)(*args, **kwargs)

    def call_async(self, method, *args, **kwargs):
        fut = Future()
        try:
            fut.set_result(getattr(self._actor, method)(*args, **kwargs))
        except Exception as e:
            fut.set_exception(e)
        return fut

    def cast(self, method, *args, **kwargs):
        getattr(self._actor, method)(*args, **kwargs)


def build_sync_plane(tmpdir, n_sources=3, plan_ahead=2):
    tel = Telemetry(enabled=True, seed=0)
    paths = materialize_group(coyo_like_specs(n_sources, seed=11),
                              str(tmpdir))
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    sched = StaticSchedule({s: 1.0 for s in paths})
    loaders, raw = {}, {}
    for src, path in sorted(paths.items()):
        loader = SourceLoader(src, path, (0, 1), workers=1,
                              buffer_target=64, seed=3, telemetry=tel)
        loader.name = f"loader:{src}:0of1"
        loader.on_start()
        loaders[loader.name] = _SyncHandle(loader)
        raw[src] = loader
    constructors = {
        b: _SyncHandle(DataConstructor(b, tree, seq_len=128,
                                       rows_per_microbatch=2, n_bins=1,
                                       telemetry=tel))
        for b in range(tree.buckets("DP"))}
    planner = Planner(
        tree, sched, STRATEGIES["backbone_balance"],
        dict(costfn=backbone_cost(get_config("qwen3-8b")), broadcast=(),
             n_bins=1),
        loaders=loaders, constructors=constructors,
        samples_per_step=8, seed=5, plan_ahead=plan_ahead, telemetry=tel)
    return planner, raw, tel


def test_snapshot_routes_weight_away_from_open_breaker(tmp_path):
    """The merged snapshot RPC still carries the degradation signal: a
    source whose circuit breaker is open gets zero weight in the re-mixed
    schedule and contributes no samples to the plan."""
    planner, raw, tel = build_sync_plane(tmp_path)
    broken = sorted(raw)[0]
    raw[broken].breaker = CircuitBreaker(1, 999.0)
    raw[broken].breaker.record_failure()
    assert raw[broken].breaker.state == "open"

    meta, owner, degraded = planner._collect_buffers()
    assert degraded == {broken}
    assert any(m["source"] == broken for m in meta)   # buffered, not used

    planner.ensure_planned(2)
    log = planner.degraded_log()
    assert log and all(d["degraded"] == [broken] for d in log)
    assert tel.registry.counter_value(
        "planner_samples_planned_total", source=broken) == 0.0
    healthy = [s for s in raw if s != broken]
    assert sum(tel.registry.counter_value(
        "planner_samples_planned_total", source=s) for s in healthy) > 0
    hist = planner.history_window()
    broken_loader = f"loader:{broken}:0of1"
    for per_loader in hist.values():
        assert not per_loader.get(broken_loader)


def test_restore_invalidates_prefetched_history(tmp_path):
    """A recovered planner must not trust plans the dead incarnation
    prefetched past the restored checkpoint: restore_state drops history
    beyond planned_through and ensure_planned replans forward."""
    planner, raw, tel = build_sync_plane(tmp_path)
    planner.ensure_planned(1)
    planner.advance_to(5)          # prefetch frontier well ahead
    assert planner.planned_through() == 5
    state = planner.checkpoint_state()
    # simulate the crash window: the checkpoint is authoritative only
    # through step 2, but its history pickle carries prefetched steps
    state["planned_through"] = 2
    planner.restore_state(state)
    assert planner.planned_through() == 2
    assert max(planner.history_window()) <= 2
    # the new incarnation replans forward from the restored frontier
    planner.ensure_planned(4)
    assert planner.planned_through() == 4
    assert 4 in planner.history_window()


def test_prefetch_depth_gauge_tracks_frontier(tmp_path):
    """planner_prefetch_depth = planned_through - last_requested: positive
    while the pipeline runs ahead, 0 when planning is demand-driven."""
    planner, raw, tel = build_sync_plane(tmp_path)
    planner.ensure_planned(0)
    assert tel.registry.gauge_value("planner_prefetch_depth") == 0.0
    planner.advance_to(3)
    assert tel.registry.gauge_value("planner_prefetch_depth") == 3.0
