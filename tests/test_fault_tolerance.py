"""Non-interrupted fault tolerance (§6.1): shadow promotion, planner
recovery from differential checkpoints, replay-based loader recovery."""
import time

import pytest

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group


@pytest.fixture(scope="module")
def source_paths(tmp_path_factory):
    root = tmp_path_factory.mktemp("ft_sources")
    return materialize_group(coyo_like_specs(3), str(root))


def mk(source_paths, **kw):
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    cfg = get_config("qwen3-8b")
    sched = StaticSchedule({f"coyo_{i:03d}": 1.0 for i in range(3)})
    defaults = dict(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance",
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()))
    defaults.update(kw)
    return Overlord(source_paths, tree, sched,
                    OverlordConfig(**defaults)).start()


def run_steps(ov, lo, hi, timeout=15.0):
    for step in range(lo, hi):
        for r in range(ov.tree.world):
            v = ov.get_batch(step, r, timeout=timeout)
            assert v["role"] in ("data", "metadata", "none")
        ov.step_done(step)


def test_shadow_promotion_uninterrupted(source_paths):
    ov = mk(source_paths, shadows=True)
    try:
        run_steps(ov, 0, 3)
        killed = ov.inject_loader_failures(2)
        assert len(killed) == 2
        time.sleep(0.3)
        run_steps(ov, 3, 6)
        assert len(ov.shadow_mgr.promotions) == 2
        # promoted loaders re-registered under primary names + new shadows
        for name in killed:
            assert ov.loaders[name].alive
            assert ov.shadow_mgr.shadows[name].alive
        # shadow buffers were synced: delivery never raised above
        assert all(r["recovery_s"] < 1.0 for r in ov.recovery_log)
    finally:
        ov.shutdown()


def test_loader_cold_recovery_via_replay(source_paths):
    """No shadows: recovery = checkpoint + plan-history replay."""
    ov = mk(source_paths, shadows=False, loader_ckpt_every=2)
    try:
        run_steps(ov, 0, 5)
        name = ov.inject_loader_failures(1)[0]
        time.sleep(0.4)
        run_steps(ov, 5, 8)
        assert ov.loaders[name].alive
        st = ov.loaders[name].call("stats")
        assert st["buffer_depth"] > 0
    finally:
        ov.shutdown()


def test_planner_recovery_from_checkpoint(source_paths):
    ov = mk(source_paths, shadows=False, planner_ckpt_every=1, prefetch=3)
    try:
        run_steps(ov, 0, 4)
        ov.inject_planner_failure()
        time.sleep(0.4)
        run_steps(ov, 4, 8)
        assert any(r["actor"] == "planner" for r in ov.recovery_log)
        assert ov.planner.alive
    finally:
        ov.shutdown()


def test_prefetch_rides_through_planner_outage(source_paths):
    """With a deep prefetch buffer, a planner outage shorter than the
    buffered horizon causes no stall at all (paper Fig. 16 left)."""
    ov = mk(source_paths, shadows=False, prefetch=4)
    try:
        run_steps(ov, 0, 4)
        # let prefetch fill
        time.sleep(0.3)
        client = ov.clients[0]
        buffered_before = client.buffered()
        assert buffered_before >= 2
        ov.inject_planner_failure()
        t0 = time.time()
        v = ov.get_batch(4, 0, timeout=10)
        stall = time.time() - t0
        assert v is not None
        assert stall < 2.0  # served from prefetch while planner restarts
        time.sleep(0.5)
        run_steps(ov, 5, 7)
    finally:
        ov.shutdown()


def test_second_failure_before_sync_widens_replay(source_paths):
    """promote() then a SECOND failure of the same source before any
    sync(): the re-created shadow is unsynced, so its promotion must
    report synced_step=-1 (replay covers the full history window) rather
    than inheriting the first shadow's stale synced step."""
    ov = mk(source_paths, shadows=True, ledger=True)
    try:
        run_steps(ov, 0, 4)
        name = ov.inject_loader_failures(1)[0]
        time.sleep(0.4)   # supervision promotes the warm shadow
        promos = [p for p in ov.shadow_mgr.promotions if p["name"] == name]
        assert len(promos) == 1 and promos[0]["synced_step"] >= 0
        # the replacement shadow exists but has never been synced
        assert ov.shadow_mgr.synced_step(name) == -1
        # same source dies again before any step_done could sync it
        ov.loaders[name].kill()
        time.sleep(0.4)
        run_steps(ov, 4, 8)
        promos = [p for p in ov.shadow_mgr.promotions if p["name"] == name]
        assert len(promos) == 2
        assert promos[1]["synced_step"] == -1   # widened replay window
        assert ov.loaders[name].alive
        summary = ov.ledger.verify(strict=True)
        assert summary["ok"] and summary["lost"] == [] \
            and summary["duplicates"] == {}
    finally:
        ov.shutdown()


def test_checkpoint_frequencies_are_differential(source_paths):
    ov = mk(source_paths, shadows=False, planner_ckpt_every=1,
            loader_ckpt_every=4)
    try:
        run_steps(ov, 0, 6)
        planner_step = ov.store.checkpointed_step("planner")
        loader_names = [k for k in ov.loaders]
        loader_steps = [ov.store.checkpointed_step(n)
                        for n in loader_names]
        assert planner_step == 5
        assert all(s in (0, 4) for s in loader_steps)
    finally:
        ov.shutdown()
