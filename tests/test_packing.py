"""Packing / microbatch-transformation invariants (property-based)."""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the dev extra "
                         "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data import packing


@dataclasses.dataclass
class FakeSample:
    sample_id: str
    tokens: np.ndarray


def mk_samples(lengths):
    return [FakeSample(f"s{i}", np.arange(1, l + 1, dtype=np.int32))
            for i, l in enumerate(lengths)]


@given(st.lists(st.integers(1, 64), min_size=1, max_size=30),
       st.integers(32, 128), st.integers(1, 6))
@settings(max_examples=80, deadline=None)
def test_pack_invariants(lengths, seq_len, rows):
    samples = mk_samples(lengths)
    b = packing.pack_sequences(samples, seq_len, rows)
    assert b.tokens.shape == (rows, seq_len)
    packed_ids = {i for row in b.doc_ids for i in row}
    # 1) segment ids increase contiguously; padding only where seg == 0
    for r in range(rows):
        seg = b.segment_ids[r]
        mx = seg.max()
        assert set(np.unique(seg)) <= set(range(0, mx + 1))
        # tokens are nonzero exactly on segments
        assert ((b.tokens[r] != 0) == (seg != 0)).all()
        # positions restart at 0 per segment and are consecutive
        for s in range(1, mx + 1):
            pos = b.positions[r][seg == s]
            assert (pos == np.arange(len(pos))).all()
        # labels are next-token within segment, -1 at boundaries/pad
        for s in range(1, mx + 1):
            idx = np.where(seg == s)[0]
            toks = b.tokens[r][idx]
            labs = b.labels[r][idx]
            assert (labs[:-1] == toks[1:]).all()
            assert labs[-1] == -1
    # 2) every packed sample's tokens appear exactly once, in order
    for i, l in enumerate(lengths):
        if f"s{i}" not in packed_ids:
            continue
        found = False
        want = np.arange(1, min(l, seq_len) + 1, dtype=np.int32)
        for r in range(rows):
            seg = b.segment_ids[r]
            for s in range(1, seg.max() + 1):
                got = b.tokens[r][seg == s]
                if len(got) == len(want) and (got == want).all():
                    found = True
        assert found, f"sample s{i} lost"
    # 3) samples that fit are never dropped when capacity allows
    total = sum(min(l, seq_len) for l in lengths)
    if total <= rows * seq_len and all(l <= seq_len for l in lengths):
        # first-fit may still fail on adversarial splits, but with one
        # sample per row capacity it must pack everything
        if len(lengths) <= rows:
            assert len(packed_ids) == len(lengths)


@given(st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_cp_slice_partition(cp_degree, rows):
    seq_len = 16 * cp_degree * 2
    samples = mk_samples([seq_len - 2] * rows)
    b = packing.pack_sequences(samples, seq_len, rows)
    slices = [packing.cp_slice(b, r, cp_degree) for r in range(cp_degree)]
    # slices partition the sequence exactly
    tokens = np.concatenate([s.tokens for s in slices], axis=1)
    assert tokens.shape == b.tokens.shape
    assert sorted(tokens.flatten().tolist()) == \
        sorted(b.tokens.flatten().tolist())
    # zig-zag: each rank gets 2 chunks of s/(2cp)
    assert slices[0].tokens.shape[1] == seq_len // cp_degree


def test_metadata_only_has_no_payload():
    b = packing.pack_sequences(mk_samples([10, 20]), 64, 2)
    meta = packing.metadata_only(b)
    assert meta["rows"] == 2 and meta["seq_len"] == 64
    assert "tokens" not in meta
    assert meta["token_counts"][0] > 0


def test_pad_batch_roundtrip():
    b = packing.pack_sequences(mk_samples([5, 6]), 32, 2)
    bigger = packing.pad_batch(b, 5)
    assert bigger.rows == 5
    assert (bigger.segment_ids[2:] == 0).all()
    smaller = packing.pad_batch(bigger, 2)
    assert (smaller.tokens == b.tokens).all()
