"""Typed findings for the static data-plane analyzers.

Every analyzer in ``repro.analysis`` reports through a ``Report`` of
``Finding`` objects — a rule id (stable, documented in docs/ANALYSIS.md),
a severity, a human message, a location, and a fix hint.  ERROR findings
are launch blockers: the CLI exits non-zero and ``Overlord(validate=True)``
raises ``AnalysisError``; WARNING/INFO findings are surfaced but never
block.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Iterable, Optional, Sequence


class Severity(enum.IntEnum):
    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:  # "error", not "Severity.ERROR"
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str                    # stable id, e.g. "DG102"
    severity: Severity
    message: str                 # what is wrong
    where: str = ""              # file:line / object path / config name
    hint: str = ""               # how to fix it

    def as_dict(self) -> dict:
        return {"rule": self.rule, "severity": str(self.severity),
                "message": self.message, "where": self.where,
                "hint": self.hint}

    def render(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        hint = f"\n    hint: {self.hint}" if self.hint else ""
        return f"{self.rule} {self.severity}:{loc} {self.message}{hint}"


class Report:
    """Accumulates findings; rules in ``disabled`` are dropped at add()."""

    def __init__(self, disabled: Iterable[str] = ()):
        self.disabled = {d.strip().upper() for d in disabled if d.strip()}
        self.findings: list[Finding] = []

    def add(self, rule: str, severity: Severity, message: str,
            where: str = "", hint: str = "") -> Optional[Finding]:
        if rule.upper() in self.disabled:
            return None
        f = Finding(rule, severity, message, where, hint)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> "Report":
        for f in other.findings:
            if f.rule.upper() not in self.disabled:
                self.findings.append(f)
        return self

    # -- queries ----------------------------------------------------------
    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity >= Severity.ERROR]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == Severity.WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self, rule: str) -> list[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def __len__(self) -> int:
        return len(self.findings)

    # -- rendering ---------------------------------------------------------
    def as_text(self) -> str:
        if not self.findings:
            return "analysis: clean (0 findings)"
        lines = [f.render() for f in sorted(
            self.findings, key=lambda f: (-int(f.severity), f.rule))]
        lines.append(f"analysis: {len(self.errors)} error(s), "
                     f"{len(self.warnings)} warning(s), "
                     f"{len(self.findings)} total")
        return "\n".join(lines)

    def as_json(self) -> str:
        return json.dumps({
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "findings": [f.as_dict() for f in self.findings],
        }, indent=2)


class AnalysisError(RuntimeError):
    """Raised by launch-time validation when ERROR findings exist."""

    def __init__(self, report: Report):
        self.report = report
        n = len(report.errors)
        super().__init__(
            f"static analysis found {n} launch-blocking problem(s):\n"
            + "\n".join(f.render() for f in report.errors))


def make_report(report: Optional[Report] = None,
                disabled: Sequence[str] = ()) -> Report:
    return report if report is not None else Report(disabled)
