"""Config cross-validator (rule families CFG3xx and MDL4xx).

Checks an ``OverlordConfig`` (optionally against the trainer's
``ClientPlaceTree`` and the strategy registry) and ``ModelConfig`` model
definitions for inconsistencies that otherwise surface as hangs, silent
imbalance, or packing drops at train time: seq_len vs packing headroom
vs rows_per_microbatch, bucket count vs mesh/DP degree, prefetch vs
loader buffer depth, missing/unknown strategy params.
"""
from __future__ import annotations

import inspect
from typing import Optional

from repro.analysis.findings import Report, Severity, make_report
from repro.configs.base import ModelConfig
from repro.core.orchestrator import OverlordConfig
from repro.core.placetree import ClientPlaceTree
from repro.core.resilience import validate_positive_policy
from repro.core.strategies import STRATEGIES

# mean tokens/sample the orchestrator uses when auto-sizing a step
# (Overlord.start keeps the same constant)
EST_TOKENS_PER_SAMPLE = 96

_KNOWN_FAMILIES = {"dense", "moe", "hybrid", "vlm", "audio", "ssm"}
_KNOWN_DTYPES = {"bfloat16", "float32", "float16"}
_KNOWN_REMAT = {"none", "layer", "dots_saveable"}


# --------------------------------------------------------------- overlord
def lint_overlord_config(cfg: OverlordConfig,
                         tree: Optional[ClientPlaceTree] = None,
                         n_sources: Optional[int] = None,
                         strategies: Optional[dict] = None,
                         report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    strategies = STRATEGIES if strategies is None else strategies
    where = "OverlordConfig"

    # CFG301 — dimensions that must be positive
    for field, minimum in (("seq_len", 1), ("rows_per_microbatch", 1),
                           ("n_bins", 1), ("buffer_target", 1),
                           ("vocab_size", 2)):
        v = getattr(cfg, field)
        if v < minimum:
            rep.add("CFG301", Severity.ERROR,
                    f"{field}={v} must be >= {minimum}", where,
                    "zero/negative sizes wedge packing and planning")
    if cfg.prefetch < 0 or cfg.samples_per_step < 0:
        rep.add("CFG301", Severity.ERROR,
                f"prefetch={cfg.prefetch} / samples_per_step="
                f"{cfg.samples_per_step} must be >= 0", where,
                "0 means 'auto' for samples_per_step, never negative")

    # CFG302 — packing headroom must be a usable fraction
    if not (0.0 < cfg.fill_factor <= 1.0):
        rep.add("CFG302", Severity.ERROR,
                f"fill_factor={cfg.fill_factor} outside (0, 1]", where,
                "fill_factor is the packed-row occupancy target; "
                "1.0 packs to the brim, <=0 selects no samples")

    # CFG303 — strategy must exist
    if cfg.strategy not in strategies:
        rep.add("CFG303", Severity.ERROR,
                f"unknown strategy {cfg.strategy!r}", where,
                f"known strategies: {sorted(strategies)}")
    else:
        _lint_strategy_params(cfg, strategies[cfg.strategy], rep, where)

    # CFG308 — differential checkpoint frequencies
    if cfg.planner_ckpt_every < 1 or cfg.loader_ckpt_every < 1:
        rep.add("CFG308", Severity.ERROR,
                f"checkpoint frequencies must be >= 1 (planner="
                f"{cfg.planner_ckpt_every}, loader="
                f"{cfg.loader_ckpt_every})", where,
                "a frequency of 0 disables the replay window the "
                "recovery path depends on")
    elif cfg.loader_ckpt_every < cfg.planner_ckpt_every:
        rep.add("CFG308", Severity.WARNING,
                f"loader_ckpt_every={cfg.loader_ckpt_every} < "
                f"planner_ckpt_every={cfg.planner_ckpt_every} inverts "
                "differential checkpointing", where,
                "loaders carry the heavy buffers; checkpoint them less "
                "often than the planner and cover the gap with replay")

    # CFG309 — resilience knobs (retry / breaker / DLQ)
    if not validate_positive_policy(cfg.retry):
        rep.add("CFG309", Severity.ERROR,
                f"retry policy is degenerate (max_attempts="
                f"{cfg.retry.max_attempts}, base_delay_s="
                f"{cfg.retry.base_delay_s}, max_delay_s="
                f"{cfg.retry.max_delay_s}, multiplier="
                f"{cfg.retry.multiplier}, jitter={cfg.retry.jitter})",
                where,
                "a policy needs >= 1 attempt, non-negative delays with "
                "base <= max, multiplier >= 1 and jitter in [0, 1]")
    if cfg.breaker_failures < 1:
        rep.add("CFG309", Severity.ERROR,
                f"breaker_failures={cfg.breaker_failures} must be >= 1",
                where,
                "the circuit breaker opens after this many consecutive "
                "read failures; < 1 would open on a healthy source")
    if cfg.breaker_cooldown_s < 0:
        rep.add("CFG309", Severity.ERROR,
                f"breaker_cooldown_s={cfg.breaker_cooldown_s} must be "
                ">= 0", where,
                "the cooldown gates the half-open probe")
    if cfg.dlq_capacity < 1:
        rep.add("CFG309", Severity.ERROR,
                f"dlq_capacity={cfg.dlq_capacity} must be >= 1", where,
                "corrupted samples are quarantined here instead of "
                "killing the loader; the queue needs room for at least "
                "one entry")

    # CFG310 — pipelined planning knobs
    if cfg.plan_ahead < 0:
        rep.add("CFG310", Severity.ERROR,
                f"plan_ahead={cfg.plan_ahead} must be >= 0", where,
                "plan_ahead is the background planning lookahead window; "
                "0 disables pipelining, negative values are meaningless")
    elif cfg.plan_ahead > 0 and cfg.prefetch == 0:
        rep.add("CFG310", Severity.WARNING,
                f"plan_ahead={cfg.plan_ahead} with prefetch=0", where,
                "the lookahead only hides planner latency when clients "
                "prefetch; enable client prefetch to benefit from "
                "pipelined planning")

    # CFG311 — durable job-recovery knobs (manifest cadence / retention)
    if cfg.manifest_every < 1 or cfg.keep_epochs < 1:
        rep.add("CFG311", Severity.ERROR,
                f"manifest_every={cfg.manifest_every} / keep_epochs="
                f"{cfg.keep_epochs} must be >= 1", where,
                "manifest_every paces the atomic epoch commit point and "
                "keep_epochs is the corruption-fallback depth; < 1 "
                "leaves the job without a resumable epoch")
    elif cfg.checkpoint_dir \
            and cfg.loader_ckpt_every % max(cfg.manifest_every, 1) != 0:
        rep.add("CFG311", Severity.WARNING,
                f"manifest_every={cfg.manifest_every} does not divide "
                f"loader_ckpt_every={cfg.loader_ckpt_every}", where,
                "actor cuts land only on steps divisible by BOTH "
                "cadences; misaligned cadences stretch the replay "
                "window a resume must cover")

    # tree-dependent rules
    if tree is not None:
        _lint_against_tree(cfg, tree, n_sources, rep, where)
    return rep


def _lint_strategy_params(cfg: OverlordConfig, fn, rep: Report,
                          where: str):
    """CFG304 — strategy_params must satisfy the strategy signature."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return
    supplied = set(cfg.strategy_params) | {"schedule", "total", "n_bins"}
    for p in list(sig.parameters.values())[1:]:
        if p.kind == inspect.Parameter.VAR_KEYWORD:
            return  # **params swallows anything; nothing to check
        if p.default is inspect.Parameter.empty and p.name not in supplied:
            rep.add("CFG304", Severity.ERROR,
                    f"strategy {cfg.strategy!r} requires parameter "
                    f"{p.name!r} but strategy_params does not provide it",
                    where,
                    f"add {p.name!r} to OverlordConfig.strategy_params")
    valid = set(sig.parameters) - {"ctx"}
    for key in cfg.strategy_params:
        if key not in valid:
            rep.add("CFG304", Severity.ERROR,
                    f"strategy_params key {key!r} is not accepted by "
                    f"strategy {cfg.strategy!r}", where,
                    f"accepted params: {sorted(valid)}")


def _lint_against_tree(cfg: OverlordConfig, tree: ClientPlaceTree,
                       n_sources: Optional[int], rep: Report, where: str):
    axis = cfg.strategy_params.get("axis", "DP")

    # CFG305 — distribute/broadcast axes must exist in the mesh tree
    known = set(tree.names) | {"WORLD"}
    if axis not in known:
        rep.add("CFG305", Severity.ERROR,
                f"distribute axis {axis!r} not in the client tree "
                f"(axes: {tree.names})", where,
                "constructors are created per bucket at this axis; an "
                "unknown axis raises inside Overlord.start()")
        return
    for b in cfg.strategy_params.get("broadcast", ()) or ():
        if b not in known:
            rep.add("CFG305", Severity.ERROR,
                    f"broadcast axis {b!r} not in the client tree "
                    f"(axes: {tree.names})", where,
                    "broadcast_at() with an unknown axis raises at the "
                    "first plan")

    # CFG306 — step sizing vs packing capacity (bucket count vs DP degree)
    nb = tree.buckets(axis)
    capacity_tokens = nb * cfg.n_bins * cfg.rows_per_microbatch \
        * cfg.seq_len
    sps = cfg.samples_per_step or max(
        nb * cfg.n_bins,
        int(capacity_tokens * cfg.fill_factor / EST_TOKENS_PER_SAMPLE))
    if sps < nb * cfg.n_bins:
        rep.add("CFG306", Severity.ERROR,
                f"samples_per_step={sps} cannot populate "
                f"{nb} bucket(s) x {cfg.n_bins} bin(s)", where,
                "every microbatch bin needs at least one sample or the "
                "train step receives an empty packed batch")
    elif sps * EST_TOKENS_PER_SAMPLE > capacity_tokens:
        rep.add("CFG306", Severity.WARNING,
                f"samples_per_step={sps} (~{sps * EST_TOKENS_PER_SAMPLE} "
                f"tokens) exceeds packing capacity {capacity_tokens} "
                f"tokens ({nb} buckets x {cfg.n_bins} bins x "
                f"{cfg.rows_per_microbatch} rows x {cfg.seq_len})", where,
                "overflow samples are silently dropped by the packer; "
                "lower samples_per_step or raise rows_per_microbatch")

    # CFG307 — prefetch pressure vs loader buffer depth
    if n_sources:
        per_source_demand = -(-sps // n_sources)  # ceil
        if cfg.buffer_target < per_source_demand:
            rep.add("CFG307", Severity.WARNING,
                    f"buffer_target={cfg.buffer_target} < ~"
                    f"{per_source_demand} samples a single step draws "
                    f"per source ({n_sources} sources, prefetch="
                    f"{cfg.prefetch})", where,
                    "a skewed mix() can drain a loader buffer mid-step "
                    "and stall prefetch; raise buffer_target")


# ------------------------------------------------------------------ model
def lint_model_config(cfg: ModelConfig,
                      report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    where = f"ModelConfig:{cfg.name}"

    # MDL401 — attention head geometry
    if cfg.num_heads < 1 or cfg.num_layers < 1 or cfg.d_model < 1:
        rep.add("MDL401", Severity.ERROR,
                f"non-positive core dims (layers={cfg.num_layers}, "
                f"d_model={cfg.d_model}, heads={cfg.num_heads})", where,
                "")
        return rep
    if cfg.head_dim == 0 and cfg.d_model % cfg.num_heads != 0:
        rep.add("MDL401", Severity.ERROR,
                f"d_model={cfg.d_model} not divisible by num_heads="
                f"{cfg.num_heads} and no explicit head_dim", where,
                "set head_dim explicitly when q_dim != d_model")

    # MDL402 — GQA grouping
    if cfg.num_kv_heads < 1 or cfg.num_heads % cfg.num_kv_heads != 0:
        rep.add("MDL402", Severity.ERROR,
                f"num_kv_heads={cfg.num_kv_heads} must divide "
                f"num_heads={cfg.num_heads}", where,
                "GQA repeats each kv head num_heads/num_kv_heads times")

    # MDL403 — MoE routing
    if cfg.num_experts > 0:
        if not (0 < cfg.experts_per_token <= cfg.num_experts):
            rep.add("MDL403", Severity.ERROR,
                    f"experts_per_token={cfg.experts_per_token} outside "
                    f"(0, num_experts={cfg.num_experts}]", where,
                    "top-k routing needs 1 <= k <= E")
        if cfg.capacity_factor <= 0:
            rep.add("MDL403", Severity.ERROR,
                    f"capacity_factor={cfg.capacity_factor} must be > 0",
                    where, "")
    elif cfg.experts_per_token > 0:
        rep.add("MDL403", Severity.ERROR,
                f"experts_per_token={cfg.experts_per_token} set but "
                "num_experts=0", where,
                "either declare the expert pool or drop the router")

    # MDL404 — family-specific input expectations
    if cfg.family not in _KNOWN_FAMILIES:
        rep.add("MDL404", Severity.ERROR,
                f"unknown family {cfg.family!r}", where,
                f"known families: {sorted(_KNOWN_FAMILIES)}")
    if cfg.family == "vlm" and cfg.image_token_frac <= 0:
        rep.add("MDL404", Severity.WARNING,
                "vlm config with image_token_frac=0 never sees image "
                "embeds", where, "set image_token_frac > 0")
    if cfg.family == "audio" and cfg.encoder_layers <= 0:
        rep.add("MDL404", Severity.WARNING,
                "audio config without encoder layers", where,
                "set encoder_layers > 0")

    # MDL405 — vocab / numerics enums
    if cfg.vocab_size < 2:
        rep.add("MDL405", Severity.ERROR,
                f"vocab_size={cfg.vocab_size} must be >= 2", where, "")
    if cfg.dtype not in _KNOWN_DTYPES:
        rep.add("MDL405", Severity.ERROR,
                f"unknown dtype {cfg.dtype!r}", where,
                f"known: {sorted(_KNOWN_DTYPES)}")
    if cfg.remat not in _KNOWN_REMAT:
        rep.add("MDL405", Severity.ERROR,
                f"unknown remat policy {cfg.remat!r}", where,
                f"known: {sorted(_KNOWN_REMAT)}")
    return rep


def lint_shipped_model_configs(report: Optional[Report] = None) -> Report:
    """Validate every registered config in repro.configs."""
    from repro.configs import get_config, list_configs
    rep = make_report(report)
    for name in list_configs():
        lint_model_config(get_config(name), rep)
    return rep
