"""CLI: static data-plane lint.

    python -m repro.analysis.lint [paths...] [--format text|json]
                                  [--disable DG108,CFG307] [--strict]

With no paths the full shipped surface is linted: the STRATEGIES
registry, every registered model config, the default OverlordConfig
against a representative client tree, and an actor-concurrency scan of
``src/repro``.  Paths may be .py files or directories: directories are
scanned for Actor subclasses; .py files are additionally imported so
``ModelConfig`` / ``OverlordConfig`` / ``STRATEGIES`` objects they
define get cross-validated (this is how CI lints config fixtures).

Exit status: 0 when no ERROR findings remain, 1 otherwise
(``--strict`` also fails on warnings).
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys
from typing import Optional

from repro.analysis.actor_lint import lint_actor_paths, lint_actor_source
from repro.analysis.perf_lint import lint_perf_paths, lint_perf_source
from repro.analysis.telemetry_lint import (
    lint_observability_paths, lint_observability_source,
)
from repro.analysis.config_lint import (
    lint_model_config, lint_overlord_config, lint_shipped_model_configs,
)
from repro.analysis.findings import Report, Severity
from repro.analysis.strategy_lint import lint_strategies, lint_strategy
from repro.configs.base import ModelConfig
from repro.core.orchestrator import OverlordConfig
from repro.core.placetree import ClientPlaceTree


def default_tree() -> ClientPlaceTree:
    """Representative topology for tree-dependent config rules."""
    return ClientPlaceTree([("PP", 1), ("DP", 4), ("CP", 1), ("TP", 1)])


def lint_default_surface(rep: Report) -> Report:
    lint_strategies(report=rep)
    lint_shipped_model_configs(report=rep)
    # representative launch config (the bare OverlordConfig() default has
    # no costfn and is deliberately rejected by CFG304 — see quickstart)
    cfg = OverlordConfig(strategy_params=dict(
        costfn=lambda meta: float(meta.get("text_tokens", 1))))
    lint_overlord_config(cfg, tree=default_tree(), n_sources=4,
                         report=rep)
    return rep


def _import_path(path: str):
    name = "_repro_lint_" + os.path.splitext(
        os.path.basename(path))[0].replace("-", "_")
    spec = importlib.util.spec_from_file_location(name, path)
    if spec is None or spec.loader is None:
        raise ImportError(path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    try:
        spec.loader.exec_module(mod)
    finally:
        sys.modules.pop(name, None)
    return mod


def lint_python_file(path: str, rep: Report) -> Report:
    """Actor scan + import-based config/strategy validation of one file."""
    with open(path, encoding="utf-8") as f:
        src = f.read()
    lint_actor_source(src, path, rep)
    lint_observability_source(src, path, rep)
    lint_perf_source(src, path, rep)
    try:
        mod = _import_path(path)
    except BaseException as e:  # fixture may raise anything at import
        rep.add("CLI901", Severity.ERROR,
                f"cannot import {path}: {type(e).__name__}: {e}", path,
                "the file must be importable for config/strategy "
                "validation; actor rules above ran on the source only")
        return rep
    tree = default_tree()
    for attr in sorted(vars(mod)):
        obj = getattr(mod, attr)
        if isinstance(obj, ModelConfig):
            lint_model_config(obj, rep)
        elif isinstance(obj, OverlordConfig):
            lint_overlord_config(obj, tree=tree, report=rep)
    strategies = getattr(mod, "STRATEGIES", None)
    if isinstance(strategies, dict):
        for name, fn in strategies.items():
            if callable(fn):
                lint_strategy(str(name), fn, rep)
    return rep


def run(paths: list[str], disabled: list[str]) -> Report:
    rep = Report(disabled)
    if not paths:
        lint_default_surface(rep)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        lint_actor_paths([src], rep)
        lint_observability_paths([src], rep)
        lint_perf_paths([src], rep)
        return rep
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                in_configs = os.path.basename(root) == "configs"
                for fn in sorted(files):
                    if not fn.endswith(".py"):
                        continue
                    full = os.path.join(root, fn)
                    if in_configs:
                        # config packages get the full import-based
                        # ModelConfig / OverlordConfig validation
                        lint_python_file(full, rep)
                    else:
                        lint_actor_paths([full], rep)
                        lint_observability_paths([full], rep)
                        lint_perf_paths([full], rep)
        elif p.endswith(".py"):
            lint_python_file(p, rep)
        else:
            rep.add("CLI902", Severity.ERROR,
                    f"unsupported path {p!r} (expected .py file or "
                    "directory)", p, "")
    return rep


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static linter for the OVERLORD data plane")
    ap.add_argument("paths", nargs="*",
                    help=".py files or directories; default: full "
                         "shipped surface")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--disable", default="",
                    help="comma-separated rule ids to suppress")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on warnings too")
    args = ap.parse_args(argv)

    rep = run(args.paths, args.disable.split(","))
    print(rep.as_json() if args.format == "json" else rep.as_text())
    failed = rep.errors or (args.strict and rep.warnings)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
