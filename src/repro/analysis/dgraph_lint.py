"""Pipeline linter over DGraph instances (rule family DG1xx).

Validates the sample-lifecycle state machine
(BUFFERED -> SELECTED -> COSTED -> BUCKETED -> BINNED -> DELIVERED)
against each node's recorded edge history, checks bucket/bin membership
consistency, and detects cycles / dangling references in the DAG formed
by ``DNode.parents``.  Operates on metadata only — linting a planned step
is as cheap as planning it.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.findings import Report, Severity, make_report
from repro.core.dgraph import (
    BINNED, BUCKETED, BUFFERED, COSTED, DELIVERED, DGraph, SELECTED,
)

# lifecycle order; transitions may skip forward (vanilla never costs)
# but never move backward.
LIFECYCLE = [BUFFERED, SELECTED, COSTED, BUCKETED, BINNED, DELIVERED]
_ORDER = {s: i for i, s in enumerate(LIFECYCLE)}

# edge labels written by DGraph mutators -> the state they imply
_LABEL_STATE = {
    "buffered": BUFFERED,
    "mix": SELECTED, "select": SELECTED, "selected": SELECTED,
    "cost": COSTED, "costed": COSTED,
    "bucket": BUCKETED, "bucketed": BUCKETED,
    "bin": BINNED, "binned": BINNED,
    "deliver": DELIVERED, "delivered": DELIVERED,
}


def _derived_states(node) -> list[str]:
    """Reconstruct the state sequence from the node's edge history."""
    out = []
    for label, _value in node.edges:
        state = _LABEL_STATE.get(str(label).lower())
        if state is not None:
            out.append(state)
    return out


def lint_dgraph(g: DGraph, *, n_buckets: Optional[int] = None,
                n_bins: Optional[int] = None,
                report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    ids = {n.nid for n in g.nodes}
    seen_samples: dict[str, int] = {}

    for n in g.nodes:
        where = f"dgraph:{g.name}/node:{n.nid}"

        # DG101 — unknown lifecycle state
        if n.state not in _ORDER:
            rep.add("DG101", Severity.ERROR,
                    f"node {n.nid} ({n.meta.get('sample_id', '?')}) is in "
                    f"unknown state {n.state!r}",
                    where, f"states must be one of {LIFECYCLE}")
            continue

        # DG102 — state-machine regression in the recorded history
        seq = _derived_states(n)
        prev = -1
        for s in seq:
            if _ORDER[s] < prev:
                rep.add("DG102", Severity.ERROR,
                        f"node {n.nid} regressed to {s!r} after reaching "
                        f"{LIFECYCLE[prev]!r}",
                        where,
                        "apply mix/cost/distribute/pack in lifecycle "
                        "order; re-costing after bucketing balances on "
                        "stale costs")
                break
            prev = max(prev, _ORDER[s])

        # DG103 — membership fields inconsistent with the state field
        order = _ORDER[n.state]
        if order >= _ORDER[BUCKETED] and n.bucket is None:
            rep.add("DG103", Severity.ERROR,
                    f"node {n.nid} is {n.state!r} but has no bucket",
                    where, "assign_buckets() must cover every node that "
                           "reaches BUCKETED")
        if n.bucket is not None and order < _ORDER[BUCKETED]:
            rep.add("DG103", Severity.ERROR,
                    f"node {n.nid} has bucket={n.bucket} but state "
                    f"{n.state!r} predates BUCKETED", where,
                    "use assign_buckets() so state and membership agree")
        if order >= _ORDER[BINNED] and n.bin is None:
            rep.add("DG103", Severity.ERROR,
                    f"node {n.nid} is {n.state!r} but has no microbatch bin",
                    where, "assign_bins() must cover every node that "
                           "reaches BINNED")
        if n.bin is not None and n.bucket is None:
            rep.add("DG103", Severity.ERROR,
                    f"node {n.nid} has bin={n.bin} but no bucket "
                    "(orphaned microbatch member)", where,
                    "bins are defined within a bucket; assign buckets "
                    "first")

        # DG104 — bucket/bin index out of the declared range
        if n.bucket is not None and n_buckets is not None \
                and not (0 <= n.bucket < n_buckets):
            rep.add("DG104", Severity.ERROR,
                    f"node {n.nid} bucket={n.bucket} outside "
                    f"[0, {n_buckets})", where,
                    "distribute() declared fewer buckets than the "
                    "strategy assigned")
        if n.bin is not None and n_bins is not None \
                and not (0 <= n.bin < n_bins):
            rep.add("DG104", Severity.ERROR,
                    f"node {n.nid} bin={n.bin} outside [0, {n_bins})",
                    where, "microbatches() declared fewer bins than the "
                           "strategy assigned")

        # DG106 — dangling parent reference
        for p in n.parents:
            if p not in ids:
                rep.add("DG106", Severity.ERROR,
                        f"node {n.nid} references parent {p} not present "
                        f"in dgraph {g.name!r}", where,
                        "derive()d views share nodes; parents must stay "
                        "within the graph that owns the node")

        # DG107 — duplicate sample ids break lineage and plan ownership
        sid = n.meta.get("sample_id")
        if sid is not None:
            if sid in seen_samples:
                rep.add("DG107", Severity.ERROR,
                        f"duplicate sample_id {sid!r} "
                        f"(nodes {seen_samples[sid]} and {n.nid})", where,
                        "lineage() and the Planner's owner map assume "
                        "sample ids are unique per graph")
            else:
                seen_samples[sid] = n.nid

    # DG105 — cycle over parent edges (the DAG must stay a DAG)
    _check_cycles(g, ids, rep)

    # DG108 — orphans left behind once the graph reached packing
    if any(n.state in _ORDER and _ORDER[n.state] >= _ORDER[BINNED]
           for n in g.nodes):
        stragglers = [n.nid for n in g.nodes
                      if n.state in (SELECTED, COSTED)]
        if stragglers:
            rep.add("DG108", Severity.WARNING,
                    f"{len(stragglers)} node(s) stalled before BUCKETED "
                    f"while others reached BINNED (e.g. node "
                    f"{stragglers[0]})", f"dgraph:{g.name}",
                    "a strategy that bins any node should bin every "
                    "selected node or drop it explicitly")
    return rep


def _check_cycles(g: DGraph, ids: set, rep: Report):
    WHITE, GREY, BLACK = 0, 1, 2
    color = {nid: WHITE for nid in ids}
    by_id = {n.nid: n for n in g.nodes}
    for start in ids:
        if color[start] != WHITE:
            continue
        stack: list[tuple[int, int]] = [(start, 0)]
        color[start] = GREY
        while stack:
            nid, i = stack[-1]
            parents = [p for p in by_id[nid].parents if p in ids]
            if i < len(parents):
                stack[-1] = (nid, i + 1)
                p = parents[i]
                if color[p] == GREY:
                    rep.add("DG105", Severity.ERROR,
                            f"cycle through nodes {p} -> {nid} in dgraph "
                            f"{g.name!r}", f"dgraph:{g.name}/node:{nid}",
                            "the DGraph must stay acyclic: a sample "
                            "cannot depend on its own downstream "
                            "transformation")
                    return
                if color[p] == WHITE:
                    color[p] = GREY
                    stack.append((p, 0))
            else:
                color[nid] = BLACK
                stack.pop()


def lint_dgraphs(graphs: Sequence[DGraph],
                 report: Optional[Report] = None, **kw) -> Report:
    rep = make_report(report)
    for g in graphs:
        lint_dgraph(g, report=rep, **kw)
    return rep
