"""Performance analyzer (rule family PERF7xx).

The pipelined planning path (docs/PERFORMANCE.md) exists because serial
actor round-trips stack up linearly: a loop over N handles that issues a
blocking ``h.call(...)`` per iteration pays N mailbox latencies where
one overlapped ``call_async`` wave (``FanOut``) pays ~1.

PERF701 flags exactly that shape in ``core/`` files: a synchronous
``.call(...)`` whose receiver is derived from the target of an enclosing
``for`` loop (i.e. the handle being iterated).  Loops that are serial on
purpose — operator introspection, the measured non-pipelined baseline —
opt out with a ``# perf: serial ok`` comment on the loop header, the
call line, or the line directly above the call.

``call_async``/``cast`` receivers never match (they do not block), and
neither does a blocking call on a FIXED handle inside a step loop
(``for step ...: self.planner.call(...)``) — that is one round-trip per
step, not per handle.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, Optional

from repro.analysis.findings import Report, Severity, make_report

#: opt-out annotation (anywhere in the comment text)
SERIAL_OK_RE = re.compile(r"#\s*perf:\s*serial\s+ok")


def _annotated_lines(source: str) -> set[int]:
    return {i for i, line in enumerate(source.splitlines(), start=1)
            if SERIAL_OK_RE.search(line)}


def _target_names(node: ast.AST) -> set[str]:
    """Names bound by a loop target (``for name, h in ...`` -> {name, h})."""
    out: set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Store):
            out.add(sub.id)
    return out


def _mentions_any(node: ast.AST, names: set[str]) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                and sub.id in names:
            return True
    return False


class _SerialCallLinter(ast.NodeVisitor):
    """PERF701 — blocking per-handle call() inside a loop over handles."""

    def __init__(self, where: str, rep: Report, annotated: set[int]):
        self.where = where
        self.rep = rep
        self.annotated = annotated
        # stack of (loop lineno, loop-bound names, loop annotated?)
        self._loops: list[tuple[int, set[str], bool]] = []

    def visit_For(self, node: ast.For):
        self._loops.append((node.lineno, _target_names(node.target),
                            node.lineno in self.annotated))
        for stmt in node.body:
            self.visit(stmt)
        self._loops.pop()
        for stmt in node.orelse:
            self.visit(stmt)

    visit_AsyncFor = visit_For

    def visit_Call(self, node: ast.Call):
        self.generic_visit(node)
        if not (isinstance(node.func, ast.Attribute)
                and node.func.attr == "call"):
            return
        recv = node.func.value
        for loop_line, names, loop_ok in self._loops:
            if not _mentions_any(recv, names):
                continue   # fixed receiver: per-step, not per-handle
            if loop_ok or node.lineno in self.annotated \
                    or (node.lineno - 1) in self.annotated:
                return
            self.rep.add(
                "PERF701", Severity.WARNING,
                f"blocking call() on loop handle at line {node.lineno} "
                f"inside the loop at line {loop_line} serializes one "
                "mailbox round-trip per handle",
                f"{self.where}:{node.lineno}",
                "issue call_async per handle and gather the futures "
                "(FanOut) so the wave overlaps, or annotate the loop "
                "with '# perf: serial ok' if serial is intentional")
            return


def _is_core_file(filename: str) -> bool:
    """PERF701 scope: files under a core/ directory, except the actor
    runtime itself (actors.py implements call() and the FanOut gather
    loop)."""
    parts = filename.replace(os.sep, "/").split("/")
    return "core" in parts[:-1] and parts[-1] != "actors.py"


def lint_perf_source(source: str, filename: str = "<string>",
                     report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    if not _is_core_file(filename):
        return rep
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        rep.add("PERF700", Severity.ERROR,
                f"cannot parse {filename}: {e.msg} (line {e.lineno})",
                filename, "")
        return rep
    _SerialCallLinter(filename, rep, _annotated_lines(source)).visit(tree)
    return rep


def lint_perf_file(path: str, report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    with open(path, encoding="utf-8") as f:
        return lint_perf_source(f.read(), path, rep)


def lint_perf_paths(paths: Iterable[str],
                    report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for fn in sorted(files):
                    if fn.endswith(".py"):
                        lint_perf_file(os.path.join(root, fn), rep)
        elif p.endswith(".py"):
            lint_perf_file(p, rep)
    return rep
