"""Actor-concurrency analyzer (rule family ACT5xx).

AST pass over ``Actor`` subclasses.  The actor runtime serializes all
state access through the mailbox thread — the analyzer flags code that
breaks that model: actor state mutated from a side thread, locks held
inside an actor (a smell that state already leaks across threads),
synchronous ``call()`` a mailbox thread can block on forever,
half-implemented checkpoint/restore pairs that silently corrupt
recovery (ACT505), checkpoint keys that never round-trip through
``restore_state`` (ACT507 — saved-but-unread state silently vanishes on
durable resume), and (ACT506, data-plane modules only) actor ``call()``
sites that bypass the RetryPolicy, where one transient fault crashes
the caller.
"""
from __future__ import annotations

import ast
import inspect
import os
from typing import Iterable, Optional, Union

from repro.analysis.findings import Report, Severity, make_report

# attribute names that conventionally hold an actor's own handle —
# call()ing through one from inside the actor self-deadlocks (the
# mailbox thread waits on a future only the mailbox thread can resolve)
_SELF_HANDLE_NAMES = {"self_handle", "own_handle", "my_handle",
                      "handle_to_self"}
_THREAD_FACTORIES = {"Thread", "Timer"}
_LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                   "BoundedSemaphore"}


def _is_actor_class(cls: ast.ClassDef) -> bool:
    for base in cls.bases:
        if isinstance(base, ast.Name) and base.id.endswith("Actor"):
            return True
        if isinstance(base, ast.Attribute) and base.attr.endswith("Actor"):
            return True
    return False


def _self_attr_writes(fn: Union[ast.FunctionDef, ast.Lambda]) -> list:
    """Statements inside ``fn`` that assign/mutate ``self.<attr>``."""
    out = []
    for node in ast.walk(fn):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Attribute) \
                    and isinstance(t.value, ast.Name) \
                    and t.value.id == "self":
                out.append((t.attr, node.lineno))
    return out


def _callable_name(func: ast.AST) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def _thread_target(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "target":
            return kw.value
    if call.args:
        return call.args[0]
    return None


def _returned_dict_keys(fn: ast.FunctionDef) -> set[str]:
    """Constant string keys of every dict literal ``fn`` returns."""
    keys: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Dict):
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
    return keys


def _state_keys_read(fn: ast.FunctionDef, param: str) -> Optional[set[str]]:
    """Keys ``fn`` reads off its ``param`` dict via ``param["k"]`` /
    ``param.get("k")``.  Returns None when ``param`` is also consumed
    generically (iterated, passed on, ``.items()``/``.update`` style) —
    then every key is potentially read and nothing can be proven."""
    read: set[str] = set()
    opaque_parents: set[int] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == param:
            opaque_parents.add(id(node.value))
            if isinstance(node.slice, ast.Constant) \
                    and isinstance(node.slice.value, str):
                read.add(node.slice.value)
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "get" \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == param \
                and node.args \
                and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str):
            opaque_parents.add(id(node.func.value))
            read.add(node.args[0].value)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id == param \
                and isinstance(node.ctx, ast.Load) \
                and id(node) not in opaque_parents:
            return None   # whole-dict use: cannot prove a key unread
    return read


def _expr_mentions_self_name(node: ast.AST) -> bool:
    """True for expressions like ``self.runtime.get(self.name)``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "name" \
                and isinstance(sub.value, ast.Name) \
                and sub.value.id == "self":
            return True
    return False


class _ActorClassLinter:
    def __init__(self, cls: ast.ClassDef, where: str, rep: Report):
        self.cls = cls
        self.where = where
        self.rep = rep
        self.methods = {n.name: n for n in cls.body
                        if isinstance(n, ast.FunctionDef)}

    def run(self):
        self._check_ckpt_pair()
        self._check_ckpt_roundtrip()
        for m in self.methods.values():
            self._check_method(m)

    # ACT505 ------------------------------------------------------------
    def _check_ckpt_pair(self):
        has_ckpt = "checkpoint_state" in self.methods
        has_restore = "restore_state" in self.methods
        if has_ckpt != has_restore:
            got = "checkpoint_state" if has_ckpt else "restore_state"
            missing = "restore_state" if has_ckpt else "checkpoint_state"
            self.rep.add(
                "ACT505", Severity.ERROR,
                f"actor {self.cls.name!r} defines {got}() without "
                f"{missing}()", f"{self.where}:{self.cls.lineno}",
                "the CheckpointStore saves what checkpoint_state returns "
                "and recovery feeds it to restore_state; implementing "
                "one side silently breaks the fault-tolerance path")

    # ACT507 ------------------------------------------------------------
    def _check_ckpt_roundtrip(self):
        """checkpoint_state()'s persisted keys must round-trip through
        restore_state(): a key that is saved but never read back silently
        vanishes on recovery — the durable-manifest path then restores an
        actor that LOOKS healthy but lost state."""
        ck = self.methods.get("checkpoint_state")
        rs = self.methods.get("restore_state")
        if ck is None or rs is None:
            return   # the missing half is ACT505's finding
        saved = _returned_dict_keys(ck)
        if not saved:
            return   # non-literal payload: nothing provable statically
        params = [a.arg for a in rs.args.args if a.arg != "self"]
        if not params:
            return
        read = _state_keys_read(rs, params[0])
        if read is None:
            return   # whole-dict consumption (e.g. update/iteration)
        missing = sorted(saved - read)
        if missing:
            self.rep.add(
                "ACT507", Severity.ERROR,
                f"actor {self.cls.name!r}: restore_state() never reads "
                f"key(s) {missing} persisted by checkpoint_state()",
                f"{self.where}:{rs.lineno}",
                "every persisted key must be consumed on restore (or "
                "dropped from the checkpoint) — unread keys are state "
                "that silently fails to survive recovery")

    def _check_method(self, m: ast.FunctionDef):
        for node in ast.walk(m):
            if not isinstance(node, ast.Call):
                continue
            name = _callable_name(node.func)

            # ACT501 / ACT502 — threads and locks inside an actor
            lock_like = name in _LOCK_FACTORIES and (
                isinstance(node.func, ast.Name)
                or (isinstance(node.func, ast.Attribute)
                    and _callable_name(node.func.value) == "threading"))
            if name in _THREAD_FACTORIES:
                self._check_thread(node, m)
            elif lock_like:
                self.rep.add(
                    "ACT502", Severity.WARNING,
                    f"actor {self.cls.name!r} creates a threading."
                    f"{name} in {m.name}() (line {node.lineno})",
                    f"{self.where}:{node.lineno}",
                    "the mailbox thread already serializes actor state; "
                    "a lock means state is shared with another thread — "
                    "route that access through call()/cast() instead")

            # ACT503 / ACT504 — blocking call() from the mailbox thread
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "call":
                self._check_call(node, m)

    # ACT501 ------------------------------------------------------------
    def _check_thread(self, call: ast.Call, m: ast.FunctionDef):
        target = _thread_target(call)
        fns: list = []
        if isinstance(target, ast.Attribute) \
                and isinstance(target.value, ast.Name) \
                and target.value.id == "self" \
                and target.attr in self.methods:
            fns.append(self.methods[target.attr])
        elif isinstance(target, ast.Name):
            for sub in ast.walk(m):
                if isinstance(sub, ast.FunctionDef) \
                        and sub.name == target.id:
                    fns.append(sub)
        elif isinstance(target, ast.Lambda):
            fns.append(target)
        writes = [w for fn in fns for w in _self_attr_writes(fn)]
        if writes:
            attr, line = writes[0]
            self.rep.add(
                "ACT501", Severity.ERROR,
                f"actor {self.cls.name!r} spawns a thread in {m.name}() "
                f"whose target mutates self.{attr} (line {line}) off "
                "the mailbox thread", f"{self.where}:{call.lineno}",
                "actor state is only safe on the mailbox thread; have "
                "the side thread cast() a message back instead of "
                "writing state directly")
        else:
            self.rep.add(
                "ACT501", Severity.INFO,
                f"actor {self.cls.name!r} spawns a thread in {m.name}() "
                f"(line {call.lineno}); verify its target never touches "
                "actor state", f"{self.where}:{call.lineno}", "")

    # ACT503 / ACT504 ----------------------------------------------------
    def _check_call(self, call: ast.Call, m: ast.FunctionDef):
        recv = call.func.value  # type: ignore[union-attr]
        self_handle = (
            isinstance(recv, ast.Attribute)
            and isinstance(recv.value, ast.Name)
            and recv.value.id == "self"
            and recv.attr in _SELF_HANDLE_NAMES)
        via_registry = isinstance(recv, ast.Call) \
            and _callable_name(recv.func) == "get" \
            and any(_expr_mentions_self_name(a) for a in recv.args)
        if self_handle or via_registry:
            self.rep.add(
                "ACT503", Severity.ERROR,
                f"actor {self.cls.name!r} issues a synchronous call() on "
                f"its own handle in {m.name}() (line {call.lineno})",
                f"{self.where}:{call.lineno}",
                "the mailbox thread blocks on a future that only the "
                "mailbox thread can complete — guaranteed self-deadlock;"
                " use cast() or invoke the method directly")
        for kw in call.keywords:
            if kw.arg == "timeout" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is None:
                self.rep.add(
                    "ACT504", Severity.ERROR,
                    f"actor {self.cls.name!r} blocks on call(timeout="
                    f"None) in {m.name}() (line {call.lineno})",
                    f"{self.where}:{call.lineno}",
                    "an unbounded call() inside an actor method can "
                    "wedge the mailbox forever if the peer dies; pass a "
                    "finite timeout")


class _CallRetryLinter(ast.NodeVisitor):
    """ACT506 — data-plane call() sites must not bypass RetryPolicy.

    Flags ``<handle>.call("method", ...)`` outside any ``try`` and
    without a ``retry=`` keyword in files under ``core/``.  There, one
    transient fault (actor restarting, mailbox timeout) propagates
    straight into the caller — the planner or supervisor — and takes the
    data plane down with it.  Only the ``except`` path of a ``try``
    counts as protection; ``orelse``/``finally`` run unguarded.
    """

    def __init__(self, where: str, rep: Report):
        self.where = where
        self.rep = rep
        self._try_depth = 0

    def visit_Try(self, node: ast.Try):
        self._try_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self._try_depth -= 1
        for part in (node.handlers, node.orelse, node.finalbody):
            for stmt in part:
                self.visit(stmt)

    def visit_Call(self, node: ast.Call):
        self.generic_visit(node)
        if not (isinstance(node.func, ast.Attribute)
                and node.func.attr == "call"
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            return
        if self._try_depth > 0 \
                or any(kw.arg == "retry" for kw in node.keywords):
            return
        method = node.args[0].value
        self.rep.add(
            "ACT506", Severity.WARNING,
            f"bare actor call({method!r}) at line {node.lineno} "
            "bypasses RetryPolicy and is not inside try",
            f"{self.where}:{node.lineno}",
            "pass retry=<RetryPolicy> (or wrap in try) so a transient "
            "actor fault degrades the step instead of crashing the "
            "caller")


def _is_data_plane_file(filename: str) -> bool:
    """ACT506 scope: files under a core/ directory, except the actor
    runtime itself (actors.py implements the retry mechanism)."""
    parts = filename.replace(os.sep, "/").split("/")
    return "core" in parts[:-1] and parts[-1] != "actors.py"


def lint_actor_source(source: str, filename: str = "<string>",
                      report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        rep.add("ACT500", Severity.ERROR,
                f"cannot parse {filename}: {e.msg} (line {e.lineno})",
                filename, "")
        return rep
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and _is_actor_class(node):
            _ActorClassLinter(node, filename, rep).run()
    if _is_data_plane_file(filename):
        _CallRetryLinter(filename, rep).visit(tree)
    return rep


def lint_actor_file(path: str, report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    with open(path, encoding="utf-8") as f:
        return lint_actor_source(f.read(), path, rep)


def lint_actor_paths(paths: Iterable[str],
                     report: Optional[Report] = None) -> Report:
    """Lint every .py file under the given files/directories."""
    rep = make_report(report)
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for fn in sorted(files):
                    if fn.endswith(".py"):
                        lint_actor_file(os.path.join(root, fn), rep)
        elif p.endswith(".py"):
            lint_actor_file(p, rep)
    return rep


def lint_actor_class(cls: type, report: Optional[Report] = None) -> Report:
    """Lint a live Actor subclass via its source (tests, REPL)."""
    rep = make_report(report)
    try:
        src = inspect.getsource(cls)
    except (OSError, TypeError):
        return rep
    import textwrap
    return lint_actor_source(textwrap.dedent(src),
                             getattr(cls, "__module__", "<class>"), rep)
