"""Static analysis for the OVERLORD data plane (docs/ANALYSIS.md).

Three analyzers, one finding model:

  * pipeline linter   — DGraph state machine + strategy contracts
                        (dgraph_lint, strategy_lint; rules DG1xx/ST2xx)
  * config validator  — OverlordConfig x ClientPlaceTree x ModelConfig
                        cross-checks (config_lint; rules CFG3xx/MDL4xx)
  * actor analyzer    — concurrency rules over Actor subclasses
                        (actor_lint; rules ACT5xx)
  * observability     — shared-counter hygiene in core/ files
                        (telemetry_lint; rules OBS6xx)
  * performance       — serial per-handle RPC loops in core/ files
                        (perf_lint; rules PERF7xx)

``validate_launch`` is the composition ``Overlord(validate=True)`` runs
before spawning anything; ``python -m repro.analysis.lint`` is the same
set of checks as a CI gate.
"""
from __future__ import annotations

from typing import Optional

from repro.analysis.actor_lint import (  # noqa: F401
    lint_actor_class, lint_actor_file, lint_actor_paths,
    lint_actor_source,
)
from repro.analysis.config_lint import (  # noqa: F401
    lint_model_config, lint_overlord_config, lint_shipped_model_configs,
)
from repro.analysis.dgraph_lint import (  # noqa: F401
    LIFECYCLE, lint_dgraph, lint_dgraphs,
)
from repro.analysis.findings import (  # noqa: F401
    AnalysisError, Finding, Report, Severity,
)
from repro.analysis.perf_lint import (  # noqa: F401
    lint_perf_file, lint_perf_paths, lint_perf_source,
)
from repro.analysis.strategy_lint import (  # noqa: F401
    lint_strategies, lint_strategy,
)
from repro.analysis.telemetry_lint import (  # noqa: F401
    lint_observability_file, lint_observability_paths,
    lint_observability_source,
)


def validate_launch(cfg, tree=None, n_sources: Optional[int] = None,
                    disabled=()) -> Report:
    """Launch-time validation: the selected strategy's contract plus the
    OverlordConfig cross-checks against the actual client tree."""
    from repro.core.strategies import STRATEGIES
    rep = Report(disabled)
    lint_overlord_config(cfg, tree=tree, n_sources=n_sources, report=rep)
    fn = STRATEGIES.get(cfg.strategy)
    if fn is not None:
        lint_strategy(cfg.strategy, fn, rep)
    return rep
