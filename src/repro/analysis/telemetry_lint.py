"""Observability analyzer (rule family OBS6xx).

The telemetry plane (docs/TELEMETRY.md) gives every shared counter one
home: the ``MetricsRegistry``.  A data-plane component that reaches into
ANOTHER object and bumps a counter-looking attribute directly —
``self.dlq._total += 1`` — creates a second book of record that the
``telemetry_report()`` reconciliation can never audit, and mutates state
the owning object guards with its own lock (or mailbox thread).

OBS601 flags exactly that shape in ``core/`` files: an assignment or
augmented assignment whose target is a counter-named attribute reached
through a base other than ``self``/``cls``.  Mutating *your own*
counters (``self._dropped += 1``) is fine — that is the owner keeping
its books; the registry mirrors them via instrumented paths.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, Optional

from repro.analysis.findings import Report, Severity, make_report

#: attribute names that read as counters/tallies
COUNTER_NAME_RE = re.compile(
    r"(_total$)|(_counts?$)|(_failures$)|(_dropped$)|(_quarantined$)"
    r"|(^n_)|(_errors$)")


def _unwrap_target(node: ast.AST) -> Optional[ast.Attribute]:
    """Peel Subscripts (``x._counts[k]`` -> ``x._counts``) down to the
    attribute being mutated, if any."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return node if isinstance(node, ast.Attribute) else None


def _flatten_targets(node: ast.AST) -> list:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            out.extend(_flatten_targets(elt))
        return out
    return [node]


def _base_name(attr: ast.Attribute) -> str:
    """Rendered base expression of an attribute, e.g. ``self.dlq`` for
    ``self.dlq._total``."""
    try:
        return ast.unparse(attr.value)
    except Exception:  # pragma: no cover - unparse exists on 3.9+
        return "<expr>"


def _is_foreign_counter_write(attr: ast.Attribute) -> bool:
    if not COUNTER_NAME_RE.search(attr.attr):
        return False
    base = attr.value
    # self._dropped / cls._seen: the owner's own books — allowed
    if isinstance(base, ast.Name) and base.id in ("self", "cls"):
        return False
    return True


class _ObservabilityLinter(ast.NodeVisitor):
    def __init__(self, where: str, rep: Report):
        self.where = where
        self.rep = rep

    def _check_targets(self, targets: Iterable[ast.AST], lineno: int):
        for raw in targets:
            for t in _flatten_targets(raw):
                attr = _unwrap_target(t)
                if attr is None or not _is_foreign_counter_write(attr):
                    continue
                self.rep.add(
                    "OBS601", Severity.WARNING,
                    f"shared counter {_base_name(attr)}.{attr.attr} "
                    f"mutated directly at line {lineno}",
                    f"{self.where}:{lineno}",
                    "counters owned by another component must go through "
                    "its API or the telemetry MetricsRegistry "
                    "(inc/observe); direct writes bypass the owner's "
                    "locking and the telemetry_report() reconciliation")

    def visit_Assign(self, node: ast.Assign):
        self._check_targets(node.targets, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_targets([node.target], node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            self._check_targets([node.target], node.lineno)
        self.generic_visit(node)


def _is_core_file(filename: str) -> bool:
    parts = filename.replace(os.sep, "/").split("/")
    return "core" in parts[:-1]


def lint_observability_source(source: str, filename: str = "<string>",
                              report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    if not _is_core_file(filename):
        return rep
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        rep.add("OBS600", Severity.ERROR,
                f"cannot parse {filename}: {e.msg} (line {e.lineno})",
                filename, "")
        return rep
    _ObservabilityLinter(filename, rep).visit(tree)
    return rep


def lint_observability_file(path: str,
                            report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    with open(path, encoding="utf-8") as f:
        return lint_observability_source(f.read(), path, rep)


def lint_observability_paths(paths: Iterable[str],
                             report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                for fn in sorted(files):
                    if fn.endswith(".py"):
                        lint_observability_file(os.path.join(root, fn),
                                                rep)
        elif p.endswith(".py"):
            lint_observability_file(p, rep)
    return rep
