"""Strategy-contract linter (rule family ST2xx).

A strategy is ``fn(ctx: Orchestration, *, schedule, total, **params) ->
LoadingPlan``.  This module checks every ``STRATEGIES`` entry against
that contract statically: the signature via ``inspect`` and the body via
``ast`` (primitive call order, return shape, typo'd primitives) — so a
bad composition fails at lint/launch time instead of hanging the first
training step.
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Callable, Optional

from repro.analysis.findings import Report, Severity, make_report
from repro.core.primitives import Orchestration

# the declarative surface a strategy may invoke on ctx
CTX_PRIMITIVES = {name for name in dir(Orchestration)
                  if not name.startswith("__")}
# primitives that must precede others (caller line order)
_ORDER_RULES = [
    ("mix", "plan", "plan() emits the LoadingPlan; only mix()ed samples "
                    "participate in orchestration"),
    ("mix", "dgraph", "dgraph() snapshots the mix() selection; building "
                      "it first plans over the raw buffer"),
    ("distribute", "balance", "balance() needs the bucket count that "
                              "distribute() declares"),
    ("cost", "balance", "balance() packs by per-sample cost; without "
                        "cost() every sample weighs 0"),
]


def _ctx_param(fn: Callable) -> Optional[str]:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return None
    params = list(sig.parameters.values())
    return params[0].name if params else None


def lint_strategy(name: str, fn: Callable,
                  report: Optional[Report] = None) -> Report:
    rep = make_report(report)
    where = f"strategy:{name}"

    # ---- signature contract (inspect) --------------------------------
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        rep.add("ST207", Severity.WARNING,
                f"strategy {name!r} has no introspectable signature",
                where, "wrap builtins/partials in a def with the "
                       "(ctx, *, schedule, total, ...) contract")
        return rep
    params = list(sig.parameters.values())
    if not params or params[0].kind not in (
            inspect.Parameter.POSITIONAL_ONLY,
            inspect.Parameter.POSITIONAL_OR_KEYWORD):
        rep.add("ST201", Severity.ERROR,
                f"strategy {name!r} must take the Orchestration ctx as "
                "its first positional parameter", where,
                "def strategy(ctx, *, schedule, total, ...)")
    for required in ("schedule", "total"):
        if required not in sig.parameters:
            rep.add("ST201", Severity.ERROR,
                    f"strategy {name!r} does not accept {required!r} "
                    "(the Planner always passes it)", where,
                    "add a keyword-only parameter "
                    f"'{required}' to the signature")
    for p in params[1:]:
        if p.kind == inspect.Parameter.POSITIONAL_OR_KEYWORD:
            rep.add("ST201", Severity.ERROR,
                    f"strategy {name!r} parameter {p.name!r} must be "
                    "keyword-only", where,
                    "insert '*' after ctx: strategy params travel as "
                    "**strategy_params and positional ones silently "
                    "shadow them")
    ret = sig.return_annotation
    ret_name = getattr(ret, "__name__", str(ret))
    if ret is inspect.Signature.empty or "LoadingPlan" not in ret_name:
        rep.add("ST202", Severity.WARNING,
                f"strategy {name!r} is not annotated '-> LoadingPlan'",
                where, "annotate the return type so the contract is "
                       "explicit")

    # ---- body contract (ast) -----------------------------------------
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError):
        rep.add("ST207", Severity.WARNING,
                f"strategy {name!r} has no retrievable source; body "
                "rules skipped", where, "")
        return rep
    fdef = next((n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))),
                None)
    if fdef is None:
        return rep
    ctx_name = _ctx_param(fn) or "ctx"

    calls: dict[str, list[int]] = {}
    for node in ast.walk(fdef):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == ctx_name:
            prim = node.func.attr
            calls.setdefault(prim, []).append(node.lineno)
            # ST206 — typo'd / unknown primitive would AttributeError at
            # plan time (inside the Planner actor, i.e. a wedged step)
            if prim not in CTX_PRIMITIVES:
                rep.add("ST206", Severity.ERROR,
                        f"strategy {name!r} calls unknown primitive "
                        f"ctx.{prim}() (line {node.lineno})",
                        f"{where}:{node.lineno}",
                        f"known primitives: "
                        f"{sorted(p for p in CTX_PRIMITIVES if not p.startswith('_'))}")

    # ST204 — mix() is mandatory: it defines what this step trains on
    if "mix" not in calls:
        rep.add("ST204", Severity.ERROR,
                f"strategy {name!r} never calls ctx.mix()", where,
                "call ctx.mix(schedule, total) before building dgraphs; "
                "otherwise the whole loader buffer is planned verbatim")

    # ST205 — primitive ordering
    for first, then, why in _ORDER_RULES:
        if then in calls and first in calls:
            if min(calls[first]) > min(calls[then]):
                rep.add("ST205", Severity.ERROR,
                        f"strategy {name!r} calls ctx.{then}() before "
                        f"ctx.{first}()", where, why)
        elif then in calls and first not in calls \
                and (first, then) == ("distribute", "balance"):
            # cost-before-balance only applies when both appear, and a
            # missing mix() is already ST204; distribute() is the one
            # hard prerequisite reported here
            rep.add("ST205", Severity.ERROR,
                    f"strategy {name!r} calls ctx.{then}() but never "
                    f"ctx.{first}()", where, why)

    # ST203 — every return must hand back a LoadingPlan-shaped value:
    # ctx.plan(...), plan_raw(...), or a LoadingPlan(...) constructor
    for node in ast.walk(fdef):
        if not isinstance(node, ast.Return):
            continue
        v = node.value
        ok = False
        if isinstance(v, ast.Call):
            f = v.func
            if isinstance(f, ast.Attribute) and f.attr == "plan":
                ok = True
            if isinstance(f, ast.Name) and f.id in ("plan_raw",
                                                    "LoadingPlan"):
                ok = True
        elif isinstance(v, ast.Name):
            ok = True   # returning a local; shape not statically known
        if not ok:
            rep.add("ST203", Severity.ERROR,
                    f"strategy {name!r} return at line {node.lineno} is "
                    "not a LoadingPlan (expected ctx.plan(...) / "
                    "plan_raw(...))", f"{where}:{node.lineno}",
                    "the Planner executes the returned plan's entries; "
                    "anything else raises inside the actor thread")
    return rep


def lint_strategies(strategies: Optional[dict] = None,
                    report: Optional[Report] = None) -> Report:
    """Lint a STRATEGIES registry (defaults to the shipped one)."""
    rep = make_report(report)
    if strategies is None:
        from repro.core.strategies import STRATEGIES
        strategies = STRATEGIES
    for name, fn in strategies.items():
        lint_strategy(name, fn, rep)
    return rep
