"""Self-contained AdamW (+ schedules, global-norm clipping).

Params are stored fp32 (master weights); the train step casts compute
leaves to bf16 inside the forward (MaxText-style).  Moments are fp32 and
shard exactly like their params (same tree structure -> same logical axes).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: object               # pytree like params
    nu: object               # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
