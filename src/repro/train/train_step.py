"""Loss + train/serve step factories (pjit-ready, shape-polymorphic)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import params as pdefs
from repro.models.model_zoo import Model
from repro.train.optimizer import (
    AdamWConfig, AdamWState, adamw_update, init_adamw,
)

AUX_LOSS_WEIGHT = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(model: Model, key: jax.Array) -> TrainState:
    # master weights fp32; compute casts to bf16 (see _cast_for_compute)
    params = model.init(key, jnp.float32)
    return TrainState(params=params, opt=init_adamw(params))


def abstract_train_state(model: Model) -> TrainState:
    params = model.abstract_params(jnp.float32)
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(lambda z: z, zeros))
    return TrainState(params=params, opt=opt)


def _cast_for_compute(params, compute_dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda p: p.astype(compute_dtype)
        if p.dtype == jnp.float32 and p.ndim > 1 else p, params)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean masked token xent (fp32) + accuracy."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return jnp.sum(nll) / denom, acc


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        cparams = _cast_for_compute(params)
        logits, aux = model.forward(cparams, batch)
        labels = batch["labels"]
        mask = ((labels >= 0) & (batch["segment_ids"] > 0)).astype(
            jnp.float32)
        loss, acc = cross_entropy(logits, labels, mask)
        total = loss + AUX_LOSS_WEIGHT * aux
        return total, {"loss": loss, "aux_loss": aux, "accuracy": acc,
                       "tokens": jnp.sum(mask)}
    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig()):
    loss_fn = make_loss_fn(model)

    def train_step(state: TrainState, batch):
        (total, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, total_loss=total, **opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(_cast_for_compute(params), batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(_cast_for_compute(params), cache, tokens,
                                 pos)
    return decode_step
