"""Trainer: OVERLORD data plane -> jit'd train step, with unified
checkpointing (model state + data-plane state snapshot together, so a
restart resumes both consistently).
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.orchestrator import Overlord
from repro.models.model_zoo import Model, build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (
    TrainState, init_train_state, make_train_step,
)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    """Single-process trainer consuming OVERLORD batches.

    On a real pod-slice every host runs this loop; per-host constructors
    feed local shards and `jax.make_array_from_process_local_data` forms
    the global arrays.  On this one-device container the loop assembles
    the global batch from all buckets directly.
    """

    def __init__(self, model: Model, overlord: Overlord,
                 cfg: TrainerConfig = TrainerConfig(), seed: int = 0):
        self.model = model
        self.ov = overlord
        self.cfg = cfg
        self.state = init_train_state(model, jax.random.key(seed))
        self.step_fn = jax.jit(make_train_step(model, cfg.opt))
        self.history: list[dict] = []

    def _assemble_global_batch(self, step: int) -> dict:
        """Pull every data-fetching client's view; concatenate bucket/bin
        rows into the global batch."""
        axis = self.ov.cfg.strategy_params.get("axis", "DP")
        parts = []
        for rank in self.ov.tree.data_fetching_clients(axis):
            view = self.ov.get_batch(step, rank)
            if view["role"] != "data" or view.get("cp_rank", 0) != 0:
                continue
            for b in view["bins"]:
                parts.append(b)
        tokens = np.concatenate([p.tokens for p in parts], 0)
        seg = np.concatenate([p.segment_ids for p in parts], 0)
        pos = np.concatenate([p.positions for p in parts], 0)
        labels = np.concatenate([p.labels for p in parts], 0)
        return {"tokens": tokens, "segment_ids": seg, "positions": pos,
                "labels": labels}

    def train(self, steps: Optional[int] = None) -> list[dict]:
        steps = steps or self.cfg.steps
        for step in range(steps):
            t0 = time.time()
            batch = self._assemble_global_batch(step)
            fetch_s = time.time() - t0
            t1 = time.time()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])
            rec = {"step": step, "loss": loss,
                   "accuracy": float(metrics["accuracy"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "fetch_s": round(fetch_s, 4),
                   "step_s": round(time.time() - t1, 4)}
            self.history.append(rec)
            self.ov.step_done(step, {"loss": loss})
            if step % self.cfg.log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"acc {rec['accuracy']:.3f} "
                      f"fetch {fetch_s*1e3:6.1f}ms "
                      f"step {rec['step_s']*1e3:7.1f}ms", flush=True)
            if self.cfg.ckpt_dir and step and \
                    step % self.cfg.ckpt_every == 0:
                self.save_checkpoint(step)
        return self.history

    # ------------------------------------------------- unified checkpoint
    def save_checkpoint(self, step: int):
        os.makedirs(self.cfg.ckpt_dir, exist_ok=True)
        flat, treedef = jax.tree.flatten(self.state)
        np.savez(os.path.join(self.cfg.ckpt_dir, f"model_{step}.npz"),
                 *[np.asarray(x) for x in flat])
        with open(os.path.join(self.cfg.ckpt_dir, f"meta_{step}.pkl"),
                  "wb") as f:
            pickle.dump({"step": step}, f)

    def load_checkpoint(self, step: int):
        data = np.load(os.path.join(self.cfg.ckpt_dir,
                                    f"model_{step}.npz"))
        flat = [data[k] for k in data.files]
        treedef = jax.tree.structure(self.state)
        leaves = jax.tree.leaves(self.state)
        self.state = jax.tree.unflatten(
            treedef, [jnp.asarray(a, l.dtype)
                      for a, l in zip(flat, leaves)])
