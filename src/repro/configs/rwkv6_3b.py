"""RWKV6-3B "Finch"  [arXiv:2404.05892] — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536.  WKV heads of size 64 -> 40 heads.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # d_model / rwkv_head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    rwkv_head_dim=64,
    rwkv_chunk=64,   # perf §R1: probed 32/64/128 — 64 is the bytes sweet spot
    rwkv_lora_dim=64,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-3b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, rwkv_head_dim=16,
        rwkv_chunk=16, rwkv_lora_dim=8)
