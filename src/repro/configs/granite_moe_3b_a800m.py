"""Granite-MoE-3B-A800M  [hf:ibm-granite/granite-3.0 family].

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 40 experts top-8.  (Assignment header says "MoE 40e top-8"; the
trailing note says 32 experts — we follow the structured field, 40.)
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                # per-expert intermediate size
    vocab_size=49_155,
    num_experts=40,
    experts_per_token=8,
    rope_theta=10_000.0,
    tied_embeddings=True,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-3b-a800m-reduced", num_layers=2, d_model=48,
        num_heads=6, num_kv_heads=2, d_ff=64, vocab_size=256,
        num_experts=5, experts_per_token=2, attn_chunk=32)
