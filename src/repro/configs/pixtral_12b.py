"""Pixtral-12B  [hf:mistralai/Pixtral-12B-2409] — VLM.

Backbone (mistral-nemo-like): 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072.  The pixtral-ViT frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings at backbone width,
fused into the token sequence at given positions.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,            # mistral-nemo uses head_dim 128
    d_ff=14_336,
    vocab_size=131_072,
    image_token_frac=0.25,   # 25% of sequence positions carry patch embeds
    rope_theta=1_000_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="pixtral-12b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        attn_chunk=32)
