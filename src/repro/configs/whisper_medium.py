"""Whisper-medium  [arXiv:2212.04356] — encoder-decoder audio model.

24L (x2: encoder+decoder) d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=51865.  The conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (1500 frames = 30s audio), per the assignment.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    encoder_frames=1500,
    rope_theta=10_000.0,     # we use RoPE instead of learned abs-pos (TPU-
                             # friendly, documented in DESIGN.md)
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-medium-reduced", num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        encoder_frames=24, attn_chunk=32)
