"""Zamba2-7B  [arXiv:2411.15242] — hybrid Mamba2 + shared attention blocks.

81L d_model=3584 32H (kv=32, i.e. MHA on the shared block) d_ff=14336
vocab=32000, ssm_state=64.  A single shared transformer block is applied
every ``attn_every`` Mamba2 layers (zamba2 signature: shared weights).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,            # 81 layers -> 13 shared-attn applications
    rope_theta=10_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-7b-reduced", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=16, attn_every=2, attn_chunk=32)
