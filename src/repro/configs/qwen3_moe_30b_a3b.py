"""Qwen3-MoE-30B-A3B  [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
MoE 128 experts top-8, qk_norm (qwen3 family).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,            # qwen3 family uses head_dim 128
    d_ff=768,                # per-expert intermediate size
    vocab_size=151_936,
    num_experts=128,
    experts_per_token=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-moe-30b-a3b-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256,
        num_experts=8, experts_per_token=2, attn_chunk=32)
