from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    assigned_archs,
    get_config,
    list_configs,
    register,
    shape_applicable,
)
