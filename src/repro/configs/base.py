"""Config system: model configs, input-shape configs, registry.

Every assigned architecture has one file in this package defining a
``CONFIG: ModelConfig`` with the exact published numbers, plus a
``reduced()`` variant used by CPU smoke tests.  The FULL configs are only
ever exercised through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- hybrid / ssm (Mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0          # zamba2: shared attn block every N layers
    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64
    rwkv_lora_dim: int = 64
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500   # whisper: 30s audio -> 1500 frames (stub)
    # --- vlm (pixtral) ---
    image_token_frac: float = 0.0  # fraction of sequence that is image embeds
    # --- numerics / performance knobs ---
    dtype: str = "bfloat16"
    remat: str = "layer"         # none | layer | dots_saveable
    attn_chunk: int = 1024       # kv-chunk for online-softmax attention
    scan_layers: bool = True
    norm_eps: float = 1e-6
    tied_embeddings: bool = False
    use_pallas: bool = False     # TPU-only: select Pallas kernel paths

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim()

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim()

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# long_500k needs sub-quadratic attention: only SSM / hybrid families run it
# (see DESIGN.md §4); everything else records an explicit skip.
_SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC_FAMILIES:
        return False, "long_500k skipped: quadratic full attention (DESIGN.md §4)"
    return True, ""


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    key = name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_ASSIGNED = [
    "qwen3_moe_30b_a3b", "granite_moe_3b_a800m", "granite_20b", "qwen3_8b",
    "yi_9b", "qwen3_32b", "zamba2_7b", "pixtral_12b", "whisper_medium",
    "rwkv6_3b",
]


def assigned_archs() -> list[str]:
    _ensure_loaded()
    return [a.replace("_", "-") for a in _ASSIGNED]


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _ASSIGNED + ["paper_vlm"]:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
