"""The paper's own model configurations (Table 1).

OVERLORD evaluates VLMs = {ViT-1B, ViT-2B} encoder x {Llama-12B, tMoE-25B,
Mixtral-8x7B} backbone.  We register the backbones as selectable archs and
describe the encoders by their cost models (the encoder frontend itself is
a patch-embedding stub, consistent with the assignment's VLM treatment).
"""
from repro.configs.base import ModelConfig, register

LLAMA_12B = register(ModelConfig(
    name="paper-llama-12b",
    family="vlm",
    num_layers=45,
    d_model=4608,
    num_heads=36,
    num_kv_heads=36,
    d_ff=4608 * 4,
    vocab_size=128_256,
    image_token_frac=0.25,
    rope_theta=500_000.0,
))

TMOE_25B = register(ModelConfig(
    name="paper-tmoe-25b",
    family="moe",
    num_layers=42,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2048 * 4,
    vocab_size=128_256,
    num_experts=16,
    experts_per_token=2,
))

MIXTRAL_8X7B = register(ModelConfig(
    name="paper-mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1_000_000.0,
))

# Encoder cost descriptors (#layers, #heads, hidden) for the data-plane cost
# models; see data/cost_models.py.
VIT_1B = dict(name="vit-1b", num_layers=39, num_heads=16, d_model=1408)
VIT_2B = dict(name="vit-2b", num_layers=48, num_heads=16, d_model=1664)


def reduced() -> ModelConfig:
    return LLAMA_12B.replace(
        name="paper-llama-12b-reduced", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        attn_chunk=32)
