"""Qwen3-32B  [hf:Qwen/Qwen3 family].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk_norm.
head_dim = d_model/num_heads = 80 per the assigned config.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25_600,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-32b-reduced", num_layers=2, d_model=64, num_heads=8,
        num_kv_heads=2, d_ff=160, vocab_size=256, attn_chunk=32)
