"""Yi-9B  [arXiv:2403.04652].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, llama-arch GQA.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11_008,
    vocab_size=64_000,
    rope_theta=10_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="yi-9b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, attn_chunk=32)
