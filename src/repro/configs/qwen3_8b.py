"""Qwen3-8B  [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936, qk_norm.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-8b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, attn_chunk=32)
