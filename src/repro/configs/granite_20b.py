"""Granite-20B (code)  [arXiv:2405.04324].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152, llama-arch.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,          # MQA
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=10_000.0,
))


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="granite-20b-reduced", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=1, d_ff=160, vocab_size=256, attn_chunk=32)
