"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Models annotate params and activations with *logical* axis names
(models/params.py).  A :class:`ShardingRules` maps logical names onto mesh
axes.  Resolution is shape-aware: a mapping is dropped (replicated) when the
dim is not divisible by the mesh-axis product — this is what lets one rule
table serve every assigned architecture (e.g. 24 attention heads or 40
experts cannot shard 16-way; they fall back to replication instead of
failing to lower).  Dropped mappings are recorded for the roofline report.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
from typing import Any, Iterable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as pdefs

AxisMap = dict[str, tuple[str, ...]]

# --- rule tables --------------------------------------------------------
# fsdp := ("pod", "data"); tensor := ("model",).  Axes absent from the
# active mesh are silently skipped at resolution time, so the same table
# works for the single-pod (data, model) and multi-pod (pod, data, model)
# production meshes as well as 1-device CPU test meshes.

_COMMON: AxisMap = {
    # params
    pdefs.EMBED: ("pod", "data"),
    pdefs.MLP: ("model",),
    pdefs.HEADS: ("model",),
    pdefs.KV_HEADS: (),            # GQA kv heads: replicated
    pdefs.HEAD_DIM: (),
    pdefs.VOCAB: ("model",),
    pdefs.EXPERT: ("model",),      # expert parallelism on the tensor axis
    pdefs.LAYERS: (),
    pdefs.SSM_STATE: (),
    pdefs.SSM_INNER: ("model",),
    pdefs.RWKV_HEADS: ("model",),
    pdefs.LORA: (),
    pdefs.CONV: (),
    pdefs.FRAMES: (),
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": (),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_expert": ("model",),
    "act_ssm": ("model",),
    "kv_seq": (),
    "cap": (),
}

TRAIN_RULES: AxisMap = dict(_COMMON)

# Decode: KV cache sequence dim is sharded over the tensor axis
# ("KV-sequence-parallel flash-decode", DESIGN.md §5); query heads stay
# replicated for the single-token step.
DECODE_RULES: AxisMap = dict(_COMMON)
DECODE_RULES.update({
    "kv_seq": ("model",),
    "act_heads": (),
})

# Long-context decode (batch=1): nothing to shard on the batch axis, so the
# KV/state sequence dim takes both data and tensor axes.
LONG_DECODE_RULES: AxisMap = dict(_COMMON)
LONG_DECODE_RULES.update({
    "kv_seq": ("data", "model"),
    "act_heads": (),
})


@dataclasses.dataclass
class ShardingRules:
    mesh: Optional[Mesh]
    mapping: AxisMap
    # (logical axis, dim, axes) combos that fell back to replication:
    dropped: list[tuple[str, int, tuple[str, ...]]] = dataclasses.field(
        default_factory=list)

    def resolve_axis(self, logical: Optional[str], dim: int,
                     used: set[str]) -> Optional[tuple[str, ...]]:
        """Resolve one logical axis for a dim of the given size."""
        if logical is None or self.mesh is None:
            return None
        axes = self.mapping.get(logical, ())
        axes = tuple(a for a in axes if a in self.mesh.axis_names
                     and a not in used)
        if not axes:
            return None
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        if size <= 1:
            return None
        if dim % size != 0:
            # try progressively shorter prefixes before replicating
            for cut in range(len(axes) - 1, 0, -1):
                sub = axes[:cut]
                s = 1
                for a in sub:
                    s *= self.mesh.shape[a]
                if s > 1 and dim % s == 0:
                    self.dropped.append((logical, dim, axes[cut:]))
                    return sub
            self.dropped.append((logical, dim, axes))
            return None
        return axes

    def spec(self, axes: Iterable[Optional[str]],
             shape: tuple[int, ...]) -> P:
        used: set[str] = set()
        out = []
        for logical, dim in zip(axes, shape):
            r = self.resolve_axis(logical, dim, used)
            if r is None:
                out.append(None)
            else:
                used.update(r)
                # multi-axis mappings keep tuple form even when only one
                # mesh axis survives filtering (e.g. embed -> ("pod",
                # "data") on a pod-less mesh resolves to ("data",)), so a
                # spec records whether the rule was compound
                multi = len(self.mapping.get(logical, ())) > 1
                out.append(r if (len(r) > 1 or multi) else r[0])
        return P(*out)


_current: contextvars.ContextVar[Optional[ShardingRules]] = \
    contextvars.ContextVar("sharding_rules", default=None)


def current_rules() -> Optional[ShardingRules]:
    return _current.get()


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], mapping: AxisMap = TRAIN_RULES):
    rules = ShardingRules(mesh, mapping) if mesh is not None else None
    token = _current.set(rules)
    try:
        yield rules
    finally:
        _current.reset(token)


def spec_for(axes: Iterable[Optional[str]], shape: tuple[int, ...]) -> P:
    rules = current_rules()
    if rules is None:
        return P()
    return rules.spec(axes, shape)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint to an activation (no-op outside
    a ``use_rules`` context, so tests on 1 device never see constraints)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} array")
    spec = rules.spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def param_shardings(defs, mesh: Mesh, mapping: AxisMap = TRAIN_RULES):
    """NamedSharding tree for a ParamDef tree (used for in_shardings)."""
    rules = ShardingRules(mesh, mapping)

    def one(d: pdefs.ParamDef):
        return NamedSharding(mesh, rules.spec(d.axes, d.shape))

    return jax.tree.map(one, defs, is_leaf=pdefs.is_def), rules
