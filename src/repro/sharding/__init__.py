from repro.sharding.logical import (  # noqa: F401
    ShardingRules,
    TRAIN_RULES,
    DECODE_RULES,
    LONG_DECODE_RULES,
    current_rules,
    shard,
    spec_for,
    use_rules,
    param_shardings,
)
