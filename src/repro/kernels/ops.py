"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU, pass interpret=False (or set ModelConfig.use_pallas) and the
same BlockSpecs lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.packed_attention import packed_flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas",
                                             "interpret", "block_q",
                                             "block_k"))
def packed_attention(q, k, v, q_seg, kv_seg, *, causal: bool = True,
                     use_pallas: bool = True, interpret: bool = True,
                     block_q: int = 128, block_k: int = 128):
    """Layout: q (b, h, sq, d); k/v (b, kh, sk, d); segs (b, s)."""
    if not use_pallas:
        return ref.packed_attention_ref(q, k, v, q_seg, kv_seg,
                                        causal=causal)
    return packed_flash_attention(q, k, v, q_seg, kv_seg, causal=causal,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_k"))
def decode_attention(q, k_cache, v_cache, cache_len, *,
                     use_pallas: bool = True, interpret: bool = True,
                     block_k: int = 256):
    """Layout: q (b, h, d); caches (b, kh, S, d); cache_len (b,)."""
    if not use_pallas:
        return ref.flash_decode_ref(q, k_cache, v_cache, cache_len)
    return flash_decode(q, k_cache, v_cache, cache_len, block_k=block_k,
                        interpret=interpret)
