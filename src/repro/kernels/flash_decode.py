"""Pallas TPU kernel: flash-decode (one query token vs a long KV cache).

Grid = (batch, kv_heads, kv_blocks); for each (b, kv-head) the query rows
are that head's GQA GROUP of q heads (group x d) — this keeps the MXU fed
even at decode (group>=2 for GQA archs) instead of one-row matmuls.
Online-softmax state lives in VMEM scratch across kv blocks; positions
beyond ``cache_len`` are masked.  This is the single-chip building block
of the KV-sequence-parallel decode path (each chip runs it over its KV
shard, then combines with a small psum — see models/attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, block_k: int,
                   num_kv_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cache_len = len_ref[0]
    live = ik * block_k < cache_len

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (BK, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        cols = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        mask = cols < cache_len
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, cache_len, *,
                 block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """q: (b, h, d); caches: (b, kh, S, d); cache_len: (b,) int32.
    Returns (b, h, d)."""
    b, h, d = q.shape
    kh, S = k_cache.shape[1], k_cache.shape[2]
    assert h % kh == 0
    group = h // kh
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kh, group, d)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,)),
            pl.BlockSpec((1, 1, group, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, ik: (ib, ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
