"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def packed_attention_ref(q, k, v, q_seg, kv_seg, *, causal: bool = True):
    """q: (b, h, sq, d); k, v: (b, kh, sk, d); segs: (b, s)."""
    b, h, sq, d = q.shape
    kh = k.shape[1]
    if kh != h:
        k = jnp.repeat(k, h // kh, axis=1)
        v = jnp.repeat(v, h // kh, axis=1)
    scale = d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = (q_seg[:, None, :, None] == kv_seg[:, None, None, :]) \
        & (kv_seg[:, None, None, :] > 0)
    if causal:
        sq_i = jnp.arange(sq)[:, None]
        sk_i = jnp.arange(k.shape[2])[None, :]
        mask = mask & (sq_i >= sk_i)[None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.where(mask, jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-20),
                     v.astype(jnp.float32))
    out = jnp.where((q_seg > 0)[:, None, :, None], out, 0.0)
    return out.astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, cache_len):
    """q: (b, h, d); caches: (b, kh, S, d); cache_len: (b,)."""
    b, h, d = q.shape
    kh, S = k_cache.shape[1], k_cache.shape[2]
    if kh != h:
        k_cache = jnp.repeat(k_cache, h // kh, axis=1)
        v_cache = jnp.repeat(v_cache, h // kh, axis=1)
    scale = d ** -0.5
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    mask = (jnp.arange(S)[None, None, :] < cache_len[:, None, None])
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.where(mask, jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bhk,bhkd->bhd", p / jnp.maximum(l, 1e-20),
                     v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def wkv6_ref(r, k, v, loga, u, reset):
    """Sequential WKV6 oracle.  r,k,v,loga: (b, s, h, dk) fp32; u: (h, dk);
    reset: (b, s) bool.  Returns (b, s, h, dk)."""
    b, s, h, dk = r.shape
    S = jnp.zeros((b, h, dk, dk), jnp.float32)
    outs = []
    for t in range(s):
        S = jnp.where(reset[:, t, None, None, None], 0.0, S)
        kv = jnp.einsum("bhi,bhj->bhij", k[:, t], v[:, t])
        o = jnp.einsum("bhi,bhij->bhj", r[:, t],
                       S + u[None, :, :, None] * kv)
        outs.append(o)
        S = S * jnp.exp(loga[:, t])[..., None] + kv
    return jnp.stack(outs, axis=1)
