"""Pallas TPU kernel: chunked WKV6 (RWKV6 linear-attention) forward.

Grid = (batch, heads, chunks); the chunk dim is innermost and sequential,
carrying the (dk x dv) state matrix in VMEM scratch — the linear-attention
analogue of the flash pattern.  All decay exponents are causal-range
cumulative sums (<= 0), so the kernel needs no rescaling tricks (see
models/rwkv.py for the math and the reset-penalty packing semantics).

The intra-chunk (t, s, i) tensor lives entirely in VMEM:
L=64, dk=64 -> 1 MiB fp32, the MXU-friendly sweet spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64
NEG = -1e30


def _wkv_kernel(u_ref, rst_ref, r_ref, k_ref, v_ref, loga_ref, o_ref,
                S_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)

    r = r_ref[0, 0].astype(jnp.float32)           # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)           # (L, dv)
    loga = loga_ref[0, 0].astype(jnp.float32)     # (L, dk) pure log decay
    rst = rst_ref[0].astype(jnp.int32)            # (L,) reset indicators
    u = u_ref[0].astype(jnp.float32)              # (dk,)
    S = S_ref[...]                                # (dk, dv)

    # Reset counts (exact), never folded into the fp32 decay cumsum — see
    # models/rwkv.py for the catastrophic-cancellation rationale.
    cw = jnp.cumsum(loga, axis=0)                 # incl current token
    cwm1 = cw - loga                              # excl current token
    R = jnp.cumsum(rst)                           # resets up to & incl t

    # inter-chunk: valid only while no reset has occurred in this chunk
    q_exp = jnp.where((R == 0)[:, None],
                      jnp.exp(jnp.minimum(cwm1, 0.0)), 0.0)
    o = jax.lax.dot_general((r * q_exp), S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: A[t,s] = sum_i r[t,i] k[s,i] exp(cwm1_t - cw_s),
    # s < t, valid iff R_t == R_s (no reset in (s, t])
    expo = jnp.minimum(cwm1[:, None, :] - cw[None, :, :], 0.0)
    pair_valid = (R[:, None] == R[None, :])[:, :, None]
    A = jnp.sum(jnp.where(pair_valid,
                          r[:, None, :] * k[None, :, :] * jnp.exp(expo),
                          0.0), axis=-1)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(t_i > s_i, A, 0.0)
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # diagonal bonus: (r_t . (u * k_t)) v_t
    diag = jnp.sum(r * u[None, :] * k, axis=1)
    o = o + diag[:, None] * v
    # state update
    dec = jnp.where(R[-1] == 0, jnp.exp(jnp.minimum(cw[-1], 0.0)), 0.0)
    k_hat = k * jnp.where((R[-1] == R)[:, None],
                          jnp.exp(jnp.minimum(cw[-1][None, :] - cw, 0.0)),
                          0.0)
    S_ref[...] = S * dec[:, None] + jax.lax.dot_general(
        k_hat, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)


def wkv6_forward(r, k, v, loga, u, reset, *, chunk: int = DEFAULT_CHUNK,
                 interpret: bool = True):
    """r, k, v, loga: (b, h, s, dk) fp32; u: (h, dk); reset: (b, s) bool.
    Returns o: (b, h, s, dk)."""
    b, h, s, dk = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rst = reset.astype(jnp.int32)                           # (b, s)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    blk = lambda ib, ih, ic: (ib, ih, ic, 0)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, dk), lambda ib, ih, ic: (ih, 0)),
            pl.BlockSpec((1, chunk), lambda ib, ih, ic: (ib, ic)),
            pl.BlockSpec((1, 1, chunk, dk), blk),
            pl.BlockSpec((1, 1, chunk, dk), blk),
            pl.BlockSpec((1, 1, chunk, dk), blk),
            pl.BlockSpec((1, 1, chunk, dk), blk),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dk), blk),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dk), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(u, rst, r, k, v, loga)
