"""Pallas TPU kernel: segment-aware block-skipping flash attention (fwd).

This is THE compute hot-spot the paper's load balancing targets: with
packed variable-length sequences, per-microbatch attention time is
proportional to sum(l_i^2) over segments — but ONLY if the kernel skips
(Q-block, KV-block) tiles whose segment ranges cannot intersect.  This
kernel does exactly that, making the planner's ``cost()`` model exact.

TPU mapping (DESIGN.md §2 hardware adaptation):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dim is innermost
    and sequential, carrying the online-softmax state in VMEM scratch
    (acc/m/l) across kv steps — the canonical TPU flash pattern.
  * BlockSpec tiles: q (BQ, d), k/v (BK, d) in VMEM; BQ=BK=128 aligns the
    MXU's 128x128 systolic tiles.
  * GQA without KV expansion: the k/v index_map divides the q-head index
    by the group size.
  * Tile skipping: causal skip (block fully above the diagonal) and
    segment skip (max(seg_q) < min(seg_k) or max(seg_k) < min(seg_q) —
    segment ids are nondecreasing within a packed row).  Skipped tiles do
    no MXU work; on real hardware the same predicate would drive scalar-
    prefetch DMA skipping, noted as a further optimization.

Validated in interpret mode against kernels/ref.py (pure jnp oracle).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_seg_ref, k_seg_ref, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
                 block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_seg = q_seg_ref[0, :]                       # (BQ,)
    k_seg = k_seg_ref[0, :]                       # (BK,)

    # --- tile skipping -------------------------------------------------
    causal_live = (iq * block_q + block_q - 1 >= ik * block_k) \
        if causal else True
    seg_live = jnp.logical_and(
        jnp.max(q_seg) >= jnp.min(k_seg),
        jnp.max(k_seg) >= jnp.min(q_seg))
    any_valid = jnp.logical_and(jnp.max(q_seg) > 0, jnp.max(k_seg) > 0)
    live = jnp.logical_and(jnp.logical_and(seg_live, any_valid),
                           causal_live)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)       # (BQ, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (BK, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        rows = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.logical_and(q_seg[:, None] == k_seg[None, :],
                               k_seg[None, :] > 0)
        if causal:
            mask = jnp.logical_and(mask, rows >= cols)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-20)[:, None]
        out = jnp.where((q_seg > 0)[:, None], out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def packed_flash_attention(q, k, v, q_seg, kv_seg, *, causal: bool = True,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q: (b, h, sq, d); k, v: (b, kh, sk, d); segs: (b, s) int32.
    Returns (b, h, sq, d) in q.dtype.
    """
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    group = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda ib, ih, iq, ik: (ib, iq)),
            pl.BlockSpec((1, block_k), lambda ib, ih, iq, ik: (ib, ik)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q_seg, kv_seg, q, k, v)
