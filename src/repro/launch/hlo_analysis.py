"""Parse compiled (post-SPMD) HLO text for collective traffic.

``compiled.as_text()`` shapes are per-device, so the byte totals here are
per-chip quantities; the roofline collective term is wire_bytes / link_bw.

Wire-byte estimates use ring-algorithm factors with the parsed group size n:
    all-reduce:          2 (n-1)/n * result_bytes
    all-gather:            (n-1)/n * result_bytes       (result = gathered)
    reduce-scatter:        (n-1)   * result_bytes       (input = n * result)
    all-to-all:            (n-1)/n * result_bytes
    collective-permute:              result_bytes
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g. "replica_groups={{0,1},{2,3}}" or "replica_groups=[32,16]<=[512]..."
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2  # unknown: conservative small group


@dataclasses.dataclass
class CollectiveStats:
    result_bytes: dict
    wire_bytes: dict
    counts: dict

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    result_bytes = {c: 0 for c in _COLLECTIVES}
    wire = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, _, rhs = ls.partition(" = ")
        # which collective starts the op? ("all-reduce-start" etc. included)
        op = None
        for c in _COLLECTIVES:
            m = re.search(rf"\b{re.escape(c)}(-start|-done)?\(", rhs)
            if m:
                if m.group(1) == "-done":
                    op = None  # avoid double counting start/done pairs
                else:
                    op = c
                break
        if op is None:
            continue
        result_part = rhs.split(op)[0]
        rb = _shape_bytes(result_part)
        n = _group_size(ls)
        counts[op] += 1
        result_bytes[op] += rb
        if op == "all-reduce":
            wire[op] += 2 * (n - 1) / n * rb
        elif op == "all-gather":
            wire[op] += (n - 1) / n * rb
        elif op == "reduce-scatter":
            wire[op] += (n - 1) * rb
        elif op == "all-to-all":
            wire[op] += (n - 1) / n * rb
        else:  # collective-permute
            wire[op] += rb
    return CollectiveStats(result_bytes, wire, counts)


def scan_op_histogram(hlo_text: str, ops: tuple[str, ...]) -> dict:
    """Count occurrences of arbitrary HLO ops (perf-loop diagnostics)."""
    out = {o: 0 for o in ops}
    for line in hlo_text.splitlines():
        for o in ops:
            if re.search(rf"\b{re.escape(o)}\(", line):
                out[o] += 1
    return out
