"""Serving launcher: batched prefill + decode with the KV-cache step.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model_zoo import build_model
from repro.train.train_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--use-pallas", action="store_true",
                    help="route attention through the Pallas kernels "
                         "(interpret mode on CPU)")
    args = ap.parse_args()

    if args.reduced:
        import importlib
        cfg = importlib.import_module(
            "repro.configs." + args.arch.replace("-", "_")).reduced()
    else:
        cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0), jnp.float32)

    b, s = args.batch, args.prompt_len
    max_len = s + args.gen
    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(1, cfg.vocab_size, (b, s)).astype(np.int32),
        "segment_ids": np.ones((b, s), np.int32),
        "positions": np.broadcast_to(np.arange(s, dtype=np.int32),
                                     (b, s)).copy(),
    }
    if cfg.family == "vlm":
        n = int(s * cfg.image_token_frac)
        batch["image_embeds"] = rng.normal(
            size=(b, n, cfg.d_model)).astype(np.float32) * 0.02
        batch["image_positions"] = np.broadcast_to(
            np.arange(n, dtype=np.int32), (b, n)).copy()
    if cfg.family == "audio":
        batch["enc_embeds"] = rng.normal(
            size=(b, cfg.encoder_frames, cfg.d_model)).astype(
            np.float32) * 0.02

    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    t0 = time.time()
    logits, _pref_cache = prefill(params, batch)
    logits.block_until_ready()
    print(f"prefill {b}x{s}: {time.time() - t0:.3f}s "
          f"logits={logits.shape}")

    # decode loop against a full-size cache: write the prompt by replaying
    # it through decode_step (exercises the serving path end to end)
    cache = model.init_cache(b, max_len, jnp.float32)
    toks = batch["tokens"]
    for t in range(s):
        logits, cache = decode(params, cache, toks[:, t:t + 1],
                               jnp.int32(t))
    out = []
    t0 = time.time()
    cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for t in range(s, max_len):
        out.append(np.asarray(cur)[:, 0])
        logits, cache = decode(params, cache, cur, jnp.int32(t))
        cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"decoded {args.gen} tokens x {b} seqs in {dt:.3f}s "
          f"({args.gen * b / dt:.1f} tok/s)")
    print("greedy continuations:", gen[:, :8].tolist())


if __name__ == "__main__":
    main()
