import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  Everything below is ordinary code.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    SHAPES, ModelConfig, ShapeConfig, assigned_archs, get_config,
    shape_applicable,
)
from repro.launch.hlo_cost import rollup
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import (
    batch_logical_axes, build_model, input_specs,
)
from repro.models import params as pdefs
from repro.sharding.logical import (
    DECODE_RULES, LONG_DECODE_RULES, TRAIN_RULES, ShardingRules, use_rules,
)
from repro.train.optimizer import AdamWState
from repro.train.train_step import (
    TrainState, abstract_train_state, make_decode_step, make_prefill_step,
    make_train_step,
)

_IS_TUPLE = lambda x: isinstance(x, tuple)


def rules_for(shape: ShapeConfig):
    if shape.kind != "decode":
        return TRAIN_RULES
    return LONG_DECODE_RULES if shape.name == "long_500k" else DECODE_RULES


def tree_shardings(axes_tree, shapes_tree, rules: ShardingRules, mesh):
    """Map a logical-axes tree + abstract-shapes tree -> NamedShardings."""
    ax_leaves, treedef = jax.tree.flatten(axes_tree, is_leaf=_IS_TUPLE)
    sh_leaves = treedef.flatten_up_to(shapes_tree)
    out = [NamedSharding(mesh, rules.spec(a, s.shape))
           for a, s in zip(ax_leaves, sh_leaves)]
    return jax.tree.unflatten(treedef, out)


def per_device_bytes(shardings, shapes) -> int:
    total = 0
    for sh, sp in zip(jax.tree.leaves(shardings), jax.tree.leaves(shapes)):
        shard_shape = sh.shard_shape(sp.shape)
        total += int(np.prod(shard_shape)) * jnp.dtype(sp.dtype).itemsize
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig, model) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference); N excludes embeds'
    unused rows but we keep the simple convention N = all params, with MoE
    experts scaled to the active fraction."""
    n_total = model.param_count()
    n_active = n_total
    if cfg.num_experts > 0:
        from repro.models.moe import padded_experts
        per_layer = 3 * cfg.d_model * cfg.d_ff
        n_expert_total = cfg.num_layers * padded_experts(cfg) * per_layer
        n_expert_active = cfg.num_layers * cfg.experts_per_token * per_layer
        n_active = n_total - n_expert_total + n_expert_active
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mapping = rules_for(shape)
    rules = ShardingRules(mesh, mapping)
    record = {"arch": arch, "shape": shape_name,
              "mesh": "multi" if multi_pod else "single",
              "status": "ok", "params": model.param_count()}

    t0 = time.time()
    with use_rules(mesh, mapping):
        if shape.kind == "train":
            state = abstract_train_state(model)
            specs = pdefs.logical_specs(model.defs)
            # moments shard like params; step scalar replicated
            state_shardings = TrainState(
                params=tree_shardings(specs, state.params, rules, mesh),
                opt=AdamWState(
                    step=NamedSharding(mesh, P()),
                    mu=tree_shardings(specs, state.opt.mu, rules, mesh),
                    nu=tree_shardings(specs, state.opt.nu, rules, mesh)))
            batch = input_specs(cfg, shape)
            batch_sh = tree_shardings(batch_logical_axes(cfg, shape), batch,
                                      rules, mesh)
            step = make_train_step(model)
            jitted = jax.jit(step, in_shardings=(state_shardings, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
            record["persistent_bytes_per_device"] = per_device_bytes(
                state_shardings, state)
        elif shape.kind == "prefill":
            params = model.abstract_params(jnp.float32)
            p_sh = tree_shardings(pdefs.logical_specs(model.defs), params,
                                  rules, mesh)
            batch = input_specs(cfg, shape)
            batch_sh = tree_shardings(batch_logical_axes(cfg, shape), batch,
                                      rules, mesh)
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
            lowered = jitted.lower(params, batch)
            record["persistent_bytes_per_device"] = per_device_bytes(
                p_sh, params)
        else:  # decode
            params = model.abstract_params(jnp.float32)
            p_sh = tree_shardings(pdefs.logical_specs(model.defs), params,
                                  rules, mesh)
            b, S = shape.global_batch, shape.seq_len
            cache = jax.eval_shape(
                functools.partial(model.init_cache, b, S))
            c_sh = tree_shardings(model.cache_axes(), cache, rules, mesh)
            tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            tok_sh = NamedSharding(mesh, rules.spec(("batch", None),
                                                    (b, 1)))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_decode_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
                donate_argnums=(1,))
            lowered = jitted.lower(params, cache, tokens, pos)
            record["persistent_bytes_per_device"] = \
                per_device_bytes(p_sh, params) + per_device_bytes(c_sh, cache)

    record["trace_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 2)

    ca = compiled.cost_analysis() or {}
    record["hlo_flops"] = float(ca.get("flops", -1.0))
    record["hlo_bytes"] = float(ca.get("bytes accessed", -1.0))
    try:
        ma = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not implement it
        record["memory_analysis"] = {"unavailable": str(e)[:200]}

    # Trip-count-aware roll-up (XLA cost_analysis counts while bodies once;
    # see hlo_cost.py).  These are per-device quantities.
    hlo = compiled.as_text()
    rolled = rollup(hlo)
    record["hlo_flops_rolled"] = rolled.flops
    record["hlo_bytes_rolled"] = rolled.bytes
    record["hlo_bytes_rolled_naive"] = rolled.bytes_naive
    record["collective_result_bytes"] = rolled.collective_result_bytes
    record["collective_wire_bytes"] = rolled.collective_wire_bytes
    record["collective_counts"] = rolled.collective_counts
    record["while_trips"] = rolled.while_trips[:50]
    record["model_flops"] = model_flops(cfg, shape, model)
    record["dropped_shardings"] = [
        f"{l}:{d}:{a}" for (l, d, a) in rules.dropped[:20]]
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = assigned_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m == "multi"))

    for arch, shape, multi in cells:
        mesh_name = "multi" if multi else "single"
        path = outdir / f"{arch}__{shape}__{mesh_name}.json"
        if path.exists():
            print(f"[skip existing] {path.name}")
            continue
        print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, multi)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            gib = rec["persistent_bytes_per_device"] / 2**30
            extra = (f" flops={rec['hlo_flops']:.3e}"
                     f" persistent={gib:.2f}GiB/dev"
                     f" compile={rec['compile_s']}s")
        print(f"  -> {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
