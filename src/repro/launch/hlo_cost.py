"""Trip-count-aware HLO cost roll-up.

XLA's built-in ``cost_analysis()`` counts ``while`` bodies ONCE, which
undercounts scan-over-layers programs by ~num_layers x (verified in
EXPERIMENTS.md §Dry-run methodology).  This module parses the compiled
(post-SPMD, per-device-shapes) HLO text, builds the computation call graph,
and rolls flops / bytes / collective traffic up through ``while`` loops
using the ``known_trip_count`` backend config (fallback: the loop-condition
constant).

Conventions:
  * flops        — dot_general MACs x2, multiplied through loop nests.
                   (MXU work; elementwise flops are excluded on purpose —
                   the compute roofline term targets the systolic array.)
  * bytes        — per top-level instruction: result + operand bytes,
                   skipping aliasing/no-data ops (parameter, tuple, gte,
                   bitcast, constant).  Fusion internals are NOT counted
                   (they live in registers/VMEM); the fusion instruction
                   itself contributes operands + result.  This approximates
                   HBM traffic the way HloCostAnalysis does.
  * collectives  — result-bytes and ring-model wire-bytes by op type,
                   multiplied through loop nests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_NO_DATA_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "opt-barrier", "partition-id",
    "replica-id",
})
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "ragged-all-to-all", "collective-permute")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(txt: str) -> Optional[list[int]]:
    m = _SHAPE_RE.search(txt)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return dims


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    op: str
    operands: list
    attrs: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.rtype)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    table: dict  # name -> Instr


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_OPNAME_RE = re.compile(r"^([\w\-]+)\(")


def _split_result_op(rest: str):
    """'f32[16]{0} dot(%a, %b), attrs' -> (rtype, op, operand_str, attrs)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                rtype, tail = rest[:i + 1], rest[i + 1:].strip()
                break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, tail = rest[:sp], rest[sp + 1:].strip()
    m = _OPNAME_RE.match(tail)
    if not m:
        return None
    op = m.group(1)
    depth = 0
    start = tail.find("(")
    for i in range(start, len(tail)):
        depth += tail[i] == "("
        depth -= tail[i] == ")"
        if depth == 0:
            operand_str = tail[start + 1:i]
            attrs = tail[i + 1:]
            break
    else:
        operand_str, attrs = "", ""
    return rtype, op, operand_str, attrs


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and "->" in line:
                cur = Computation(m.group(1), [], {})
            continue
        s = line.strip()
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        parsed = _split_result_op(m.group(2))
        if parsed is None:
            continue
        rtype, op, operand_str, attrs = parsed
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        ins = Instr(m.group(1), rtype, op, operands, attrs)
        cur.instrs.append(ins)
        cur.table[ins.name] = ins
    return comps


def _trip_count(ins: Instr, comps: dict) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return int(m.group(1))
    # fallback: constant in the condition computation's compare
    m = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
    if m and m.group(1) in comps:
        for ci in comps[m.group(1)].instrs:
            if ci.op == "constant":
                mc = re.search(r"constant\((\d+)\)", ci.attrs) or \
                    re.search(r"constant\((\d+)\)", ci.rtype)
                if mc:
                    return int(mc.group(1))
    return 1


def _called_comps(ins: Instr) -> list:
    out = []
    for key in ("calls", "body", "to_apply", "branch_computations"):
        m = re.search(rf"{key}=\{{?([%\w\.\-, ]+)\}}?", ins.attrs)
        if m:
            out.extend(re.findall(r"%?([\w\.\-]+)", m.group(1)))
    return out


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_dims = _first_shape_dims(ins.rtype) or []
    res_elems = 1
    for d in res_dims:
        res_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 0.0
    cdims = [int(x) for x in m.group(1).split(",") if x.strip()]
    lhs = comp.table.get(ins.operands[0])
    if lhs is None:
        return 2.0 * res_elems  # unknown K: degenerate
    ldims = _first_shape_dims(lhs.rtype) or []
    K = 1
    for c in cdims:
        if c < len(ldims):
            K *= ldims[c]
    return 2.0 * res_elems * K


@dataclasses.dataclass
class RolledCost:
    flops: float = 0.0
    bytes: float = 0.0        # aliasing-aware (DUS/DS count slice bytes)
    bytes_naive: float = 0.0  # v1 metric: operands+result for every op
    collective_result_bytes: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_wire_bytes: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    dot_count: float = 0.0
    while_trips: list = dataclasses.field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.collective_wire_bytes.values())


def _group_size(attrs: str) -> int:
    m = _GROUPS_BRACE_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    return 2


def _collective_wire(op: str, rb: float, n: int) -> float:
    if op == "all-reduce":
        return 2 * (n - 1) / n * rb
    if op == "all-gather":
        return (n - 1) / n * rb
    if op == "reduce-scatter":
        return (n - 1) * rb
    if op in ("all-to-all", "ragged-all-to-all"):
        return (n - 1) / n * rb
    return rb  # collective-permute


def rollup(text: str) -> RolledCost:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip().replace("ENTRY ", "", 1))
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: computation with most instructions
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    cost = RolledCost()

    def visit(cname: str, mult: float, in_fusion: bool):
        comp = comps.get(cname)
        if comp is None:
            return
        for ins in comp.instrs:
            base_op = ins.op.removesuffix("-start").removesuffix("-done")
            if base_op in _COLLECTIVES and not ins.op.endswith("-done"):
                rb = ins.result_bytes
                n = _group_size(ins.attrs)
                cost.collective_result_bytes[base_op] += mult * rb
                cost.collective_wire_bytes[base_op] += \
                    mult * _collective_wire(base_op, rb, n)
                cost.collective_counts[base_op] += mult
            if ins.op == "dot":
                cost.flops += mult * _dot_flops(ins, comp)
                cost.dot_count += mult
            if ins.op == "while":
                trips = _trip_count(ins, comps)
                cost.while_trips.append(trips)
                body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                if body:
                    visit(body.group(1), mult * trips, in_fusion)
                if not in_fusion:
                    cost.bytes += mult * ins.result_bytes
                continue
            if ins.op == "fusion":
                # dots inside fusions still count flops (output fusion);
                # bytes inside do not (registers/VMEM) — the fusion instr
                # itself contributes operands+result below.
                for sub in _called_comps(ins):
                    visit(sub, mult, True)
            elif ins.op in ("call", "conditional", "map"):
                for sub in _called_comps(ins):
                    visit(sub, mult, in_fusion)
                continue  # bytes accounted inside the callee
            if in_fusion:
                continue
            if ins.op in _NO_DATA_OPS:
                continue
            b = ins.result_bytes
            for opn in ins.operands:
                src = comp.table.get(opn)
                if src is not None:
                    b += src.result_bytes
            cost.bytes_naive += mult * b
            # XLA aliases dynamic-(update-)slice in place: actual traffic
            # is the slice, not the whole buffer (scan output stacking
            # otherwise dominates the memory term spuriously).
            if ins.op == "dynamic-update-slice" and len(ins.operands) > 1:
                upd = comp.table.get(ins.operands[1])
                ub = upd.result_bytes if upd else 0
                b = 2 * ub
            elif ins.op == "dynamic-slice":
                b = 2 * ins.result_bytes
            cost.bytes += mult * b

    visit(entry, 1.0, False)
    return cost
