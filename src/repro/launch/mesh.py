"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run pins
``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_degree(mesh) -> int:
    d = 1
    for a in data_axes(mesh):
        d *= mesh.shape[a]
    return d
