"""End-to-end training launcher: OVERLORD data plane + pjit train step.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 100 --strategy hybrid_balance

On a multi-host pod-slice this same entry point runs per host (jax
distributed init), with the OVERLORD actors as a CPU sidecar; on this
container it runs everything in-process.
"""
from __future__ import annotations

import argparse
import tempfile

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, CurriculumSchedule, Overlord, OverlordConfig,
    StaticSchedule,
)
from repro.data.cost_models import backbone_cost, encoder_cost
from repro.data.sources import coyo_like_specs, materialize_group
from repro.models.model_zoo import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (full configs need the pod)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--strategy", default="backbone_balance",
                    choices=["vanilla", "backbone_balance",
                             "hybrid_balance"])
    ap.add_argument("--dp", type=int, default=4)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--rows", type=int, default=2)
    ap.add_argument("--n-bins", type=int, default=1)
    ap.add_argument("--sources", type=int, default=4)
    ap.add_argument("--curriculum", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.reduced:
        import importlib
        mod = importlib.import_module(
            "repro.configs." + args.arch.replace("-", "_"))
        cfg = mod.reduced()
    else:
        cfg = get_config(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count():,}")

    root = tempfile.mkdtemp(prefix="overlord_train_")
    specs = coyo_like_specs(args.sources)
    paths = materialize_group(specs, root)
    names = [s.name for s in specs]
    if args.curriculum:
        sched = CurriculumSchedule(
            easy={names[0]: 1.0},
            hard={n: 1.0 for n in names[1:]},
            ramp_steps=max(args.steps // 2, 1))
    else:
        sched = StaticSchedule({n: 1.0 for n in names})

    sparams = {"broadcast": ("TP",) if args.tp > 1 else ()}
    if args.strategy == "hybrid_balance":
        sparams.update(backbone_costfn=backbone_cost(cfg),
                       encoder_costfn=encoder_cost(48, 1664))
    else:
        sparams.update(costfn=backbone_cost(cfg))

    tree = ClientPlaceTree([("PP", 1), ("DP", args.dp), ("CP", 1),
                            ("TP", args.tp)])
    ov = Overlord(paths, tree, sched, OverlordConfig(
        seq_len=args.seq_len, rows_per_microbatch=args.rows,
        n_bins=args.n_bins, strategy=args.strategy,
        strategy_params=sparams, vocab_size=cfg.vocab_size,
    )).start()
    try:
        trainer = Trainer(model, ov, TrainerConfig(
            steps=args.steps, ckpt_dir=args.ckpt_dir,
            opt=AdamWConfig(peak_lr=args.lr, warmup_steps=10,
                            total_steps=max(args.steps, 20))))
        hist = trainer.train()
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"(first {hist[0]['loss']:.4f})")
        print("memory:", {k: f"{v / 1e6:.1f}MB"
                          for k, v in ov.memory_report().items()})
    finally:
        ov.shutdown()


if __name__ == "__main__":
    main()
