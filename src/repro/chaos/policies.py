"""Retry / breaker / quarantine policies used by the chaos harness.

The canonical implementations live in ``repro.core.resilience`` (core
must not depend on chaos); this module is the chaos-facing surface plus
ready-made policy presets for fault drills.
"""
from __future__ import annotations

from repro.core.resilience import (  # noqa: F401
    CircuitBreaker, CorruptSampleError, DeadLetterQueue, RetryPolicy,
    TransientIOError, validate_positive_policy,
)


def aggressive_retry(seed: int = 0) -> RetryPolicy:
    """Fast, many-attempt policy for soak tests (sub-ms base delay)."""
    return RetryPolicy(max_attempts=5, base_delay_s=0.005,
                       max_delay_s=0.1, seed=seed)


def patient_retry(seed: int = 0) -> RetryPolicy:
    """Production-shaped policy: fewer attempts, longer backoff."""
    return RetryPolicy(max_attempts=3, base_delay_s=0.1,
                       max_delay_s=2.0, seed=seed)
