"""DeliveryLedger: end-to-end no-loss / no-duplicate accounting.

Records every sample's journey through the data plane:

  * ``record_planned``   — the Planner deposited it into a constructor
                           for a (step, bucket),
  * ``record_delivered`` — a trainer rank actually received it from
                           ``get_batch`` (role "data" views only),
  * ``record_dropped``   — the constructor discarded it with a reason
                           (packing overflow, queue-depth eviction),
  * ``record_quarantined`` — a loader routed it to the dead-letter queue.

``verify()`` then asserts the paper's headline §6 claim under arbitrary
fault runs: every planned sample is delivered or explicitly accounted
for (zero loss), no sample is delivered in two different steps (zero
duplicates), all ranks of a bucket saw the same sample set, and nothing
quarantined ever reached a trainer.

Thread-safe: actor threads (planner, constructors) and trainer threads
write concurrently.
"""
from __future__ import annotations

import collections
import threading
from typing import Iterable, Optional


class LedgerViolation(AssertionError):
    """A no-loss / no-duplicate invariant was broken."""


class DeliveryLedger:
    def __init__(self):
        self._lock = threading.Lock()
        # sample_id -> (step, source, bucket) of the deposit
        self._planned: dict[str, tuple[int, str, int]] = {}
        # sample_id -> set of steps it was delivered in
        self._delivered: dict[str, set] = {}
        # (step, bucket) -> rank -> frozenset(sample_ids)
        self._by_rank: dict = collections.defaultdict(dict)
        self._dropped: dict[str, str] = {}        # sample_id -> reason
        self._quarantined: dict[str, str] = {}    # sample_id -> source
        self._max_delivered_step = -1

    # -- recording --------------------------------------------------------
    def record_planned(self, step: int, sample_id: str, source: str,
                       bucket: int):
        with self._lock:
            self._planned[sample_id] = (step, source, bucket)

    def record_delivered(self, step: int, rank: int, bucket: int,
                         sample_ids: Iterable[str]):
        ids = frozenset(sample_ids)
        with self._lock:
            for sid in ids:
                self._delivered.setdefault(sid, set()).add(step)
            self._by_rank[(step, bucket)][rank] = ids
            self._max_delivered_step = max(self._max_delivered_step, step)

    def record_dropped(self, step: int, sample_id: str, reason: str):
        with self._lock:
            self._dropped[sample_id] = reason

    def record_quarantined(self, sample_id: str, source: str,
                           reason: str = ""):
        with self._lock:
            self._quarantined[sample_id] = source

    # -- persistence ------------------------------------------------------
    def snapshot(self) -> dict:
        """Point-in-time copy of the full accounting state, suitable for
        pickling into a checkpoint manifest (job-level recovery: the
        resumed process must remember what was already delivered, or the
        first post-resume verify() would report phantom duplicates/loss).
        """
        with self._lock:
            return {
                "planned": dict(self._planned),
                "delivered": {k: sorted(v)
                              for k, v in self._delivered.items()},
                "by_rank": {k: {r: sorted(ids) for r, ids in v.items()}
                            for k, v in self._by_rank.items()},
                "dropped": dict(self._dropped),
                "quarantined": dict(self._quarantined),
                "max_delivered_step": self._max_delivered_step,
            }

    def restore(self, snap: dict) -> None:
        """Inverse of ``snapshot`` — replaces this ledger's state."""
        with self._lock:
            self._planned = {k: tuple(v)
                             for k, v in snap["planned"].items()}
            self._delivered = {k: set(v)
                               for k, v in snap["delivered"].items()}
            self._by_rank = collections.defaultdict(dict)
            for key, per_rank in snap["by_rank"].items():
                self._by_rank[tuple(key) if isinstance(key, (list, tuple))
                              else key] = {
                    r: frozenset(ids) for r, ids in per_rank.items()}
            self._dropped = dict(snap["dropped"])
            self._quarantined = dict(snap["quarantined"])
            self._max_delivered_step = int(snap["max_delivered_step"])

    def delivered_ids(self) -> set:
        """All sample ids any rank has ever received (resume seeds the
        Overlord's unique-delivery telemetry set from this)."""
        with self._lock:
            return set(self._delivered)

    # -- verification -----------------------------------------------------
    def verify(self, through_step: Optional[int] = None,
               strict: bool = True) -> dict:
        """Check invariants over steps <= ``through_step`` (default: the
        last step any rank consumed).  Returns a summary dict; with
        ``strict`` raises LedgerViolation on loss or duplication."""
        with self._lock:
            planned = dict(self._planned)
            delivered = {k: set(v) for k, v in self._delivered.items()}
            by_rank = {k: dict(v) for k, v in self._by_rank.items()}
            dropped = dict(self._dropped)
            quarantined = dict(self._quarantined)
            horizon = self._max_delivered_step if through_step is None \
                else through_step

        duplicates = {sid: sorted(steps)
                      for sid, steps in delivered.items() if len(steps) > 1}
        lost = []
        for sid, (step, source, bucket) in planned.items():
            if step > horizon:
                continue   # deposited but not yet due for delivery
            if sid in delivered or sid in dropped or sid in quarantined:
                continue
            lost.append((sid, step, source))
        rank_skew = []
        for (step, bucket), per_rank in by_rank.items():
            sets = set(per_rank.values())
            if len(sets) > 1:
                rank_skew.append((step, bucket,
                                  {r: sorted(s) for r, s in
                                   per_rank.items()}))
        leaked = sorted(set(delivered) & set(quarantined))

        summary = {
            "planned": len(planned),
            "delivered": len(delivered),
            "dropped": len(dropped),
            "quarantined": len(quarantined),
            "through_step": horizon,
            "lost": sorted(lost),
            "duplicates": duplicates,
            "rank_skew": rank_skew,
            "quarantine_leaks": leaked,
        }
        summary["ok"] = not (lost or duplicates or rank_skew or leaked)
        if strict and not summary["ok"]:
            problems = []
            if lost:
                problems.append(f"{len(lost)} lost sample(s): "
                                f"{sorted(lost)[:5]}")
            if duplicates:
                problems.append(f"{len(duplicates)} duplicated sample(s): "
                                f"{sorted(duplicates.items())[:5]}")
            if rank_skew:
                problems.append(f"rank skew at {len(rank_skew)} "
                                f"(step, bucket) pair(s)")
            if leaked:
                problems.append(f"{len(leaked)} quarantined sample(s) "
                                f"delivered: {leaked[:5]}")
            raise LedgerViolation("; ".join(problems))
        return summary
