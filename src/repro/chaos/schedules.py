"""Seeded fault schedules: the reproducible timeline the injector drives.

A ``FaultSchedule`` is an ordered list of ``FaultEvent``s pinned to
training steps.  Schedules are either generated deterministically from a
seed (same seed => byte-identical schedule, the property the chaos soak
asserts) or loaded from a JSON file:

    {"version": 1, "seed": 1234,
     "events": [{"step": 7, "kind": "io_error", "target": 1,
                 "params": {"reads": 3}}, ...]}

``target`` is an index resolved against the sorted list of primary
(non-shadow) loader names at injection time, so a schedule stays valid
across runs with different loader partitionings.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Iterable, Optional

# kinds the in-process supervisor absorbs without losing the Overlord;
# generate() draws from these by default so pre-existing seeded
# timelines stay byte-identical
DEFAULT_KINDS = ("crash_loader", "crash_planner", "hang", "slow",
                 "io_error", "corrupt")
# "process_death" tears down the WHOLE ActorRuntime mid-step; recovery
# comes from the on-disk manifest via Overlord.resume (the injector
# requires a resume_factory to accept such schedules)
KINDS = DEFAULT_KINDS + ("process_death",)

# deterministic parameter menus per kind (drawn by the seeded generator);
# kept small so soak tests stay fast
_PARAM_MENU = {
    "hang": [{"seconds": 0.1}, {"seconds": 0.2}, {"seconds": 0.3}],
    "slow": [{"calls": 2, "delay": 0.02}, {"calls": 4, "delay": 0.03}],
    "io_error": [{"reads": 2}, {"reads": 4}, {"reads": 6}],
    "corrupt": [{"samples": 2}, {"samples": 4}, {"samples": 6}],
    "crash_loader": [{}],
    "crash_planner": [{}],
    "process_death": [{}],
}


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    step: int
    kind: str
    target: int = 0              # loader index (ignored for planner kinds)
    params: tuple = ()           # sorted ((key, value), ...) — hashable

    def param_dict(self) -> dict:
        return dict(self.params)

    def as_dict(self) -> dict:
        return {"step": self.step, "kind": self.kind,
                "target": self.target, "params": self.param_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(step=int(d["step"]), kind=str(d["kind"]),
                   target=int(d.get("target", 0)),
                   params=tuple(sorted(d.get("params", {}).items())))


class FaultSchedule:
    def __init__(self, events: Iterable[FaultEvent],
                 seed: Optional[int] = None):
        self.events: list[FaultEvent] = sorted(events)
        self.seed = seed
        for ev in self.events:
            if ev.kind not in KINDS:
                raise ValueError(f"unknown fault kind {ev.kind!r} "
                                 f"(known: {KINDS})")

    def events_at(self, step: int) -> list[FaultEvent]:
        return [ev for ev in self.events if ev.step == step]

    def kinds(self) -> set[str]:
        return {ev.kind for ev in self.events}

    def signature(self) -> tuple:
        """Stable value equal iff two schedules are the same timeline."""
        return tuple((ev.step, ev.kind, ev.target, ev.params)
                     for ev in self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __eq__(self, other) -> bool:
        return isinstance(other, FaultSchedule) \
            and self.signature() == other.signature()

    # -- generation -------------------------------------------------------
    @classmethod
    def generate(cls, seed: int, steps: int, rate: float = 0.12,
                 kinds: tuple = DEFAULT_KINDS, n_targets: int = 16,
                 warmup: int = 5,
                 ensure: tuple = ("crash_loader", "corrupt", "io_error"),
                 ) -> "FaultSchedule":
        """Deterministic schedule: each step after ``warmup`` draws a
        fault with probability ``rate``.  Kinds in ``ensure`` are
        guaranteed to appear at least once (inserted at deterministic
        steps if the random draw missed them), so any seed satisfies the
        soak's coverage requirements."""
        rng = random.Random(seed)
        events = []
        for step in range(warmup, steps):
            if rng.random() >= rate:
                continue
            kind = kinds[rng.randrange(len(kinds))]
            menu = _PARAM_MENU[kind]
            params = menu[rng.randrange(len(menu))]
            events.append(FaultEvent(
                step=step, kind=kind,
                target=rng.randrange(max(n_targets, 1)),
                params=tuple(sorted(params.items()))))
        present = {ev.kind for ev in events}
        missing = [k for k in ensure if k not in present]
        for i, kind in enumerate(missing):
            # spread guaranteed kinds across the middle of the run
            step = warmup + ((steps - warmup) * (i + 1)) // (len(missing) + 1)
            params = _PARAM_MENU[kind][0]
            events.append(FaultEvent(
                step=min(step, steps - 1), kind=kind,
                target=i % max(n_targets, 1),
                params=tuple(sorted(params.items()))))
        return cls(events, seed=seed)

    @classmethod
    def process_death_soak(cls, seed: int, steps: int, deaths: int = 3,
                           noise_rate: float = 0.08, warmup: int = 5,
                           n_targets: int = 16) -> "FaultSchedule":
        """Deterministic schedule for the durable-recovery soak: ``deaths``
        whole-process deaths spread evenly across the run, plus latency-
        only background noise (hang/slow).  Data-perturbing kinds
        (io_error/corrupt/crashes) are deliberately EXCLUDED — they change
        buffer state nondeterministically across incarnations, and this
        soak's exactly-once verdict requires the resumed replan to re-pick
        the same samples the dead incarnation planned."""
        rng = random.Random(seed)
        deaths = max(int(deaths), 1)
        events = []
        span = max(steps - warmup, deaths)
        for i in range(deaths):
            step = warmup + (span * (2 * i + 1)) // (2 * deaths)
            events.append(FaultEvent(step=min(step, steps - 1),
                                     kind="process_death"))
        death_steps = {ev.step for ev in events}
        for step in range(warmup, steps):
            if step in death_steps or rng.random() >= noise_rate:
                continue
            kind = ("hang", "slow")[rng.randrange(2)]
            menu = _PARAM_MENU[kind]
            events.append(FaultEvent(
                step=step, kind=kind,
                target=rng.randrange(max(n_targets, 1)),
                params=tuple(sorted(menu[rng.randrange(len(menu))]
                                    .items()))))
        return cls(events, seed=seed)

    # -- file format ------------------------------------------------------
    def save(self, path: str):
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"version": 1, "seed": self.seed,
                       "events": [ev.as_dict() for ev in self.events]},
                      f, indent=2)

    @classmethod
    def load(cls, path: str) -> "FaultSchedule":
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        if doc.get("version") != 1:
            raise ValueError(f"unsupported fault-schedule version "
                             f"{doc.get('version')!r} in {path}")
        return cls([FaultEvent.from_dict(d) for d in doc["events"]],
                   seed=doc.get("seed"))
