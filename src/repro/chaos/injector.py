"""FaultInjector: applies a seeded FaultSchedule to a live Overlord.

Wraps all three layers the paper's §6 design must survive:

  * actor runtime — ``crash_loader`` / ``crash_planner`` kill actors
    abruptly (pending mail dropped), exercising shadow promotion and
    differential-checkpoint recovery;
  * storage — ``io_error`` installs a read-fault budget into the storage
    layer's fault hook, so ``SourceReader.read`` raises TransientIOError
    and the loader's retry policy + circuit breaker absorb it;
  * data sources — ``corrupt`` poisons the next records the loader
    prepares (caught by validation, routed to the dead-letter queue),
    ``hang`` / ``slow`` wedge or delay the loader's mailbox thread.

Drive it once per training step: ``injector.on_step(step)``.  The
``timeline()`` (step, kind, resolved-target, params) is fully determined
by the schedule plus the sorted loader names, which is what the chaos
soak compares across two same-seed runs.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.chaos.schedules import FaultSchedule
from repro.core.resilience import TransientIOError
from repro.data import storage
from repro.telemetry import ensure_telemetry


class FaultInjector:
    def __init__(self, overlord, schedule: FaultSchedule,
                 install_storage_hook: bool = True,
                 resume_factory: Optional[Callable[[], object]] = None):
        """``resume_factory`` () -> started-or-resumed Overlord: required
        when the schedule contains ``process_death`` events.  On such an
        event the injector tears the CURRENT overlord's runtime down
        abruptly (``simulate_process_death``) and swaps in the factory's
        fresh incarnation (which should call ``Overlord.resume()``), so
        the soak driver keeps using ``injector.ov``."""
        self.ov = overlord
        self.schedule = schedule
        self.resume_factory = resume_factory
        if "process_death" in schedule.kinds() and resume_factory is None:
            raise ValueError(
                "schedule contains process_death events; FaultInjector "
                "needs a resume_factory to bring the job back")
        self.applied: list[tuple] = []
        self.errors: list[tuple] = []
        self.resumes: list[dict] = []
        self._lock = threading.Lock()
        self._io_budget: dict[str, int] = {}   # storage path -> fail count
        self._prev_hook = None
        self._installed = False
        if install_storage_hook:
            self.install()

    # -- storage hook ------------------------------------------------------
    def install(self):
        if not self._installed:
            self._prev_hook = storage.set_fault_hook(self._storage_hook)
            self._installed = True

    def uninstall(self):
        if self._installed:
            storage.set_fault_hook(self._prev_hook)
            self._installed = False

    def _storage_hook(self, reader, n: int):
        with self._lock:
            remaining = self._io_budget.get(reader.path, 0)
            if remaining > 0:
                self._io_budget[reader.path] = remaining - 1
                raise TransientIOError(
                    f"chaos: injected read failure on {reader.path} "
                    f"({remaining - 1} left)")
        if self._prev_hook is not None:
            self._prev_hook(reader, n)

    # -- per-step drive ----------------------------------------------------
    def primary_loaders(self) -> list[str]:
        return sorted(n for n in self.ov.loaders if "::shadow" not in n)

    def on_step(self, step: int) -> list[tuple]:
        fired = []
        for ev in self.schedule.events_at(step):
            fired.append(self._apply(step, ev))
        return fired

    def _apply(self, step: int, ev) -> tuple:
        """Apply one event.  The timeline entry is recorded BEFORE the
        action: whether a kill lands on an already-dead handle is a race
        against supervision, and the timeline two same-seed runs compare
        must not depend on it.  Action failures go to ``errors``."""
        params = ev.param_dict()
        if ev.kind == "process_death":
            entry = (step, ev.kind, "job", ev.params)
        elif ev.kind == "crash_planner":
            entry = (step, ev.kind, "planner", ev.params)
        else:
            names = self.primary_loaders()
            name = names[ev.target % len(names)]
            if ev.kind in ("corrupt", "io_error"):
                # source-level faults: a corrupted or failing FILE hits
                # every shard reading it, not one loader
                entry = (step, ev.kind, self._source_of(name), ev.params)
            else:
                entry = (step, ev.kind, name, ev.params)
        self.applied.append(entry)
        tel = ensure_telemetry(getattr(self.ov, "telemetry", None))
        with tel.span("chaos.inject", step=step,
                      target=str(entry[2])) as sp:
            sp.stamp_fault(ev.kind)
            try:
                if ev.kind == "process_death":
                    # whole-job crash: runtime torn down with no
                    # supervision, then a fresh incarnation resumes from
                    # the on-disk manifest and takes over as self.ov
                    t0 = time.time()
                    self.ov.simulate_process_death()
                    self.ov = self.resume_factory()
                    self.resumes.append({
                        "step": step, "downtime_s": time.time() - t0,
                        "report": getattr(self.ov, "resume_report", None)})
                elif ev.kind == "crash_planner":
                    self.ov.inject_planner_failure()
                elif ev.kind == "crash_loader":
                    self.ov.loaders[entry[2]].kill()
                elif ev.kind == "io_error":
                    # storage-layer fault: budgeted failures on the
                    # source's backing file, seen by every reader of it
                    path = self.ov.paths[entry[2]]
                    with self._lock:
                        self._io_budget[path] = \
                            self._io_budget.get(path, 0) \
                            + int(params.get("reads", 3))
                elif ev.kind == "corrupt":
                    for n in self.primary_loaders():
                        if self._source_of(n) == entry[2]:
                            self.ov.loaders[n].cast(
                                "inject_fault", ev.kind, **params)
                else:   # hang / slow run on the one loader
                    self.ov.loaders[entry[2]].cast(
                        "inject_fault", ev.kind, **params)
            except Exception as e:   # failed injection must not stop soak
                self.errors.append(
                    (step, ev.kind, f"{type(e).__name__}: {e}"))
                sp.set_attr("inject_error", type(e).__name__)
        tel.inc("chaos_faults_injected_total", 1.0, kind=ev.kind)
        return entry

    def _source_of(self, loader_name: str) -> str:
        cfg = self.ov._loader_cfgs.get(loader_name)
        return cfg.source if cfg is not None else loader_name.split(":")[1]

    def timeline(self) -> list[tuple]:
        return list(self.applied)
