# Chaos harness: deterministic fault injection + delivery verification
# (docs/FAULT_TOLERANCE.md).  Layering: policies/schedules/ledger are
# dependency-free; only the injector imports repro.core.
from repro.chaos.ledger import DeliveryLedger, LedgerViolation  # noqa: F401
from repro.chaos.policies import (  # noqa: F401
    CircuitBreaker, CorruptSampleError, DeadLetterQueue, RetryPolicy,
    TransientIOError,
)
from repro.chaos.schedules import FaultEvent, FaultSchedule  # noqa: F401
from repro.chaos.injector import FaultInjector  # noqa: F401
