"""Microbatch transformations: packing, padding, positions, CP slicing.

Packing merges variable-length (sub)sequences into fixed ``seq_len`` rows
with segment ids (0 = padding) and within-segment positions — exactly the
representation models/attention.py masks on, so balanced packing converts
directly into balanced attention FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PackedBatch:
    tokens: np.ndarray        # (rows, seq_len) int32
    segment_ids: np.ndarray   # (rows, seq_len) int32, 0 = pad
    positions: np.ndarray     # (rows, seq_len) int32
    labels: np.ndarray        # (rows, seq_len) int32, -1 = masked
    doc_ids: list             # per row: list of sample_ids packed into it

    @property
    def rows(self) -> int:
        return self.tokens.shape[0]

    def segment_lengths(self) -> list[list[int]]:
        out = []
        for r in range(self.rows):
            seg = self.segment_ids[r]
            lens = []
            for s in range(1, seg.max() + 1) if seg.max() > 0 else []:
                lens.append(int((seg == s).sum()))
            out.append(lens)
        return out


def pack_sequences(samples: Sequence, seq_len: int, rows: int,
                   pad_id: int = 0) -> PackedBatch:
    """First-fit packing of ``samples`` (each with .tokens) into a fixed
    (rows, seq_len) buffer.  Oversized samples are truncated to seq_len.
    Samples that don't fit are dropped (the planner sizes the selection so
    this doesn't happen in practice; tests assert on it)."""
    tokens = np.full((rows, seq_len), pad_id, np.int32)
    seg = np.zeros((rows, seq_len), np.int32)
    pos = np.zeros((rows, seq_len), np.int32)
    labels = np.full((rows, seq_len), -1, np.int32)
    fill = [0] * rows
    nseg = [0] * rows
    doc_ids: list[list[str]] = [[] for _ in range(rows)]
    for sm in samples:
        toks = np.asarray(sm.tokens, np.int32)[:seq_len]
        n = len(toks)
        if n == 0:
            continue
        # first row with room
        row = next((r for r in range(rows) if fill[r] + n <= seq_len), None)
        if row is None:
            continue
        a = fill[row]
        tokens[row, a:a + n] = toks
        nseg[row] += 1
        seg[row, a:a + n] = nseg[row]
        pos[row, a:a + n] = np.arange(n)
        labels[row, a:a + n - 1] = toks[1:]   # next-token targets
        fill[row] += n
        doc_ids[row].append(sm.sample_id)
    return PackedBatch(tokens, seg, pos, labels, doc_ids)


def pad_batch(batch: PackedBatch, rows: int) -> PackedBatch:
    """Pad/truncate to a fixed number of rows (constructor contract)."""
    cur = batch.rows
    if cur == rows:
        return batch
    if cur > rows:
        return PackedBatch(batch.tokens[:rows], batch.segment_ids[:rows],
                           batch.positions[:rows], batch.labels[:rows],
                           batch.doc_ids[:rows])
    def pad(a, fillv):
        extra = np.full((rows - cur,) + a.shape[1:], fillv, a.dtype)
        return np.concatenate([a, extra], 0)
    return PackedBatch(pad(batch.tokens, 0), pad(batch.segment_ids, 0),
                       pad(batch.positions, 0), pad(batch.labels, -1),
                       batch.doc_ids + [[] for _ in range(rows - cur)])


def cp_slice(batch: PackedBatch, cp_rank: int, cp_degree: int,
             zigzag: bool = True) -> PackedBatch:
    """Context-parallel sequence partition.  Zig-zag interleaving (pair
    chunk i with chunk 2*cp-1-i) balances causal-attention work per rank."""
    s = batch.tokens.shape[1]
    assert s % (2 * cp_degree) == 0 or not zigzag, (s, cp_degree)
    if cp_degree == 1:
        return batch
    if zigzag:
        chunk = s // (2 * cp_degree)
        idx = np.concatenate([
            np.arange(cp_rank * chunk, (cp_rank + 1) * chunk),
            np.arange((2 * cp_degree - 1 - cp_rank) * chunk,
                      (2 * cp_degree - cp_rank) * chunk)])
    else:
        chunk = s // cp_degree
        idx = np.arange(cp_rank * chunk, (cp_rank + 1) * chunk)
    return PackedBatch(batch.tokens[:, idx], batch.segment_ids[:, idx],
                       batch.positions[:, idx], batch.labels[:, idx],
                       batch.doc_ids)


def metadata_only(batch: PackedBatch) -> dict:
    """What pp>0 stages receive: shapes + segment structure, no payloads."""
    return {
        "rows": batch.rows,
        "seq_len": int(batch.tokens.shape[1]),
        "segment_counts": [int(batch.segment_ids[r].max())
                           for r in range(batch.rows)],
        "token_counts": [int((batch.segment_ids[r] > 0).sum())
                         for r in range(batch.rows)],
    }
