"""Synthetic multisource datasets with the paper's skew characteristics.

Fig. 2: token distributions in coyo700m / navit_data are heavily skewed
(98.23% of coyo text <= 64 tokens while the top 1.62% holds 9.3% of
tokens).  We generate sources whose text/image token counts follow
log-normal mixtures calibrated to that shape, and whose per-sample
transformation costs reproduce Fig. 5's heterogeneity (audio ~300x text,
image ~50x, variable-resolution spread within a modality).
"""
from __future__ import annotations

import dataclasses
import math
import os
import numpy as np

from repro.data import storage

MODALITY_COST = {  # relative per-output-token transform cost (§1, §2.3)
    "text": 1.0,
    "image": 50.0,
    "video": 120.0,
    "audio": 300.0,
}


@dataclasses.dataclass(frozen=True)
class SourceSpec:
    name: str
    modality: str            # text | image | video | audio
    n_samples: int = 2048
    text_mu: float = 3.2     # log-normal params for text token counts
    text_sigma: float = 1.1
    image_mu: float = 5.5    # log-normal params for image patch counts
    image_sigma: float = 0.9
    seed: int = 0

    @property
    def transform_cost(self) -> float:
        return MODALITY_COST[self.modality]


def coyo_like_specs(n_sources: int = 5, seed: int = 0) -> list[SourceSpec]:
    """Small source group, image-text pairs (coyo700m: 5 sources)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_sources):
        out.append(SourceSpec(
            name=f"coyo_{i:03d}", modality="image",
            text_mu=3.0 + rng.uniform(-0.3, 0.3),
            text_sigma=1.2, image_mu=5.2 + rng.uniform(-0.5, 0.8),
            image_sigma=0.8 + rng.uniform(0, 0.4), seed=seed + i))
    return out


def navit_like_specs(n_sources: int = 306, seed: int = 1) -> list[SourceSpec]:
    """Large heterogeneous production-like group (navit_data: 306 srcs)."""
    rng = np.random.default_rng(seed)
    mods = ["text"] * (n_sources // 2) + ["image"] * (n_sources // 3)
    mods += ["video"] * (n_sources // 9)
    mods += ["audio"] * (n_sources - len(mods))
    out = []
    for i, m in enumerate(mods):
        out.append(SourceSpec(
            name=f"navit_{i:03d}", modality=m,
            text_mu=2.8 + rng.uniform(0, 1.5),
            text_sigma=0.9 + rng.uniform(0, 0.6),
            image_mu=4.5 + rng.uniform(0, 1.8),
            image_sigma=0.7 + rng.uniform(0, 0.6), seed=seed * 1000 + i))
    return out


def sample_lengths(spec: SourceSpec, n: int, rng) -> tuple:
    """Draw (text_tokens, image_tokens) with Fig.-2-style skew."""
    text = np.clip(rng.lognormal(spec.text_mu, spec.text_sigma, n),
                   1, 8192).astype(np.int64)
    if spec.modality == "text":
        image = np.zeros(n, np.int64)
    else:
        image = np.clip(rng.lognormal(spec.image_mu, spec.image_sigma, n),
                        16, 16384).astype(np.int64)
    return text, image


def materialize_source(spec: SourceSpec, root: str,
                       row_group_rows: int = 256) -> str:
    """Write the source to disk; payload bytes simulate raw content."""
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, f"{spec.name}.colstore")
    if os.path.exists(path):
        return path
    rng = np.random.default_rng(spec.seed)
    text, image = sample_lengths(spec, spec.n_samples, rng)
    records = []
    for i in range(spec.n_samples):
        payload_len = int(text[i]) * 4 + int(image[i]) * 12
        records.append({
            "sample_id": f"{spec.name}/{i}",
            "text_tokens": int(text[i]),
            "image_tokens": int(image[i]),
            "modality": spec.modality,
            "transform_cost": spec.transform_cost
            * (1.0 + float(rng.uniform(0, 0.5))),
            # payload stands in for the raw bytes (decoded at transform time)
            "payload": bytes(payload_len % 251 for _ in range(
                min(payload_len, 512))),
            "seed": int(rng.integers(0, 2**31 - 1)),
        })
    storage.write_source(path, records, row_group_rows)
    return path


def materialize_group(specs: list[SourceSpec], root: str) -> dict[str, str]:
    return {s.name: materialize_source(s, root) for s in specs}
