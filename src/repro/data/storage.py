"""Columnar row-group storage (Parquet-like) with MEASURED access states.

The paper's multisource memory argument (§2.3) rests on per-open-file state:
socket, footer/schema metadata, row-group index, read buffer.  We reproduce
that faithfully: every ``SourceReader`` holds real bytes for each of those
and reports them, so the memory benchmarks (Figs. 4/5/14/15) measure actual
resident state rather than assumed constants.

File layout:  [row_group_0][row_group_1]...[footer][footer_len(8B)][MAGIC]
Row groups are pickled column dicts; the footer carries schema, per-group
(offset, nbytes, nrows) and per-group column statistics.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import pickle
import struct
import threading
from typing import Any, Iterator, Optional

MAGIC = b"OVLDCOL1"
DEFAULT_ROW_GROUP_ROWS = 256

# global registry of open readers -> fleet-wide access-state accounting
_OPEN_READERS: dict[int, "SourceReader"] = {}
_REG_LOCK = threading.Lock()

# storage-layer fault hook (repro.chaos): called at the top of every
# SourceReader.read with (reader, n); it may raise TransientIOError to
# model a storage hiccup.  One global slot — installers must restore the
# previous hook on teardown (FaultInjector does).
_FAULT_HOOK = None


def set_fault_hook(hook):
    """Install a read-fault hook; returns the previously installed one."""
    global _FAULT_HOOK
    prev, _FAULT_HOOK = _FAULT_HOOK, hook
    return prev


def clear_fault_hook():
    set_fault_hook(None)


def open_access_state_bytes() -> int:
    with _REG_LOCK:
        return sum(r.access_state_bytes for r in _OPEN_READERS.values())


def open_reader_count() -> int:
    with _REG_LOCK:
        return len(_OPEN_READERS)


def write_source(path: str, records: list[dict],
                 row_group_rows: int = DEFAULT_ROW_GROUP_ROWS) -> dict:
    """Write records (list of column dicts) as a columnar file."""
    assert records, "empty source"
    columns = sorted(records[0].keys())
    groups = []
    buf = io.BytesIO()
    for start in range(0, len(records), row_group_rows):
        chunk = records[start:start + row_group_rows]
        coldata = {c: [r[c] for r in chunk] for c in columns}
        blob = pickle.dumps(coldata, protocol=pickle.HIGHEST_PROTOCOL)
        groups.append({
            "offset": buf.tell(),
            "nbytes": len(blob),
            "nrows": len(chunk),
            "stats": {c: _col_stats(coldata[c]) for c in columns},
        })
        buf.write(blob)
    footer = {
        "schema": {c: type(records[0][c]).__name__ for c in columns},
        "columns": columns,
        "num_rows": len(records),
        "row_groups": groups,
    }
    fbytes = json.dumps(footer).encode()
    with open(path, "wb") as f:
        f.write(buf.getvalue())
        f.write(fbytes)
        f.write(struct.pack("<Q", len(fbytes)))
        f.write(MAGIC)
    return footer


def _col_stats(vals) -> dict:
    if vals and isinstance(vals[0], (int, float)):
        return {"min": min(vals), "max": max(vals),
                "sum": float(sum(vals))}
    return {"len": len(vals)}


@dataclasses.dataclass
class AccessState:
    footer_bytes: int = 0
    schema_bytes: int = 0
    index_bytes: int = 0
    buffer_bytes: int = 0
    socket_bytes: int = 8192     # connection buffers (S3/HDFS client stand-in)

    @property
    def total(self) -> int:
        return (self.footer_bytes + self.schema_bytes + self.index_bytes
                + self.buffer_bytes + self.socket_bytes)


class SourceReader:
    """One open source file == one set of access states (the unit the
    paper's Source Parallelism partitions)."""

    def __init__(self, path: str, shard: tuple[int, int] = (0, 1)):
        self.path = path
        self.shard_index, self.shard_count = shard
        self._f = open(path, "rb")
        self._f.seek(-8 - len(MAGIC), os.SEEK_END)
        flen = struct.unpack("<Q", self._f.read(8))[0]
        assert self._f.read(len(MAGIC)) == MAGIC, f"bad magic in {path}"
        self._f.seek(-8 - len(MAGIC) - flen, os.SEEK_END)
        self._footer_raw = self._f.read(flen)
        self.footer = json.loads(self._footer_raw)
        self._groups = self.footer["row_groups"]
        self._buffer: Optional[bytes] = None
        self._buffer_group: int = -1
        self._cursor = 0  # row index within this shard's row space
        # source-parallel partitioning (§5.1): a sharded reader keeps only
        # ITS row groups' index entries + proportional read-ahead buffers
        my_groups = [self._groups[g] for g in self._my_groups()]
        self.state = AccessState(
            footer_bytes=len(self._footer_raw) // self.shard_count,
            schema_bytes=len(json.dumps(self.footer["schema"]).encode()),
            index_bytes=len(json.dumps(my_groups).encode()),
        )
        with _REG_LOCK:
            _OPEN_READERS[id(self)] = self

    # shard-aware row space: reader (i, n) owns row groups g with g%n == i
    def _my_groups(self) -> list[int]:
        return [g for g in range(len(self._groups))
                if g % self.shard_count == self.shard_index]

    @property
    def num_rows(self) -> int:
        return sum(self._groups[g]["nrows"] for g in self._my_groups())

    @property
    def access_state_bytes(self) -> int:
        self.state.buffer_bytes = len(self._buffer) if self._buffer else 0
        return self.state.total

    def _load_group(self, gidx: int) -> dict:
        if gidx != self._buffer_group:
            g = self._groups[gidx]
            self._f.seek(g["offset"])
            self._buffer = self._f.read(g["nbytes"])
            self._buffer_group = gidx
        return pickle.loads(self._buffer)

    def read(self, n: int) -> list[dict]:
        """Read the next n records (wrapping around: epoch semantics)."""
        hook = _FAULT_HOOK
        if hook is not None:
            hook(self, n)   # may raise TransientIOError
        mine = self._my_groups()
        if not mine:
            return []
        total = self.num_rows
        out = []
        while len(out) < n:
            row = self._cursor % total
            # locate group
            acc = 0
            for g in mine:
                nr = self._groups[g]["nrows"]
                if row < acc + nr:
                    cols = self._load_group(g)
                    local = row - acc
                    rec = {c: v[local] for c, v in cols.items()}
                    rec["_row_id"] = f"{os.path.basename(self.path)}" \
                        f":{g}:{local}"
                    out.append(rec)
                    break
                acc += nr
            self._cursor += 1
        return out

    def seek(self, cursor: int):
        self._cursor = cursor

    def tell(self) -> int:
        return self._cursor

    def close(self):
        with _REG_LOCK:
            _OPEN_READERS.pop(id(self), None)
        self._f.close()
        self._buffer = None

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
