"""Sample transformations (the P term of the loader cost tuple (P, T, M)).

Each transform converts a raw storage record into a training-ready sample:
token ids (text) and patch-embedding token counts (image/video/audio).
Real work is done (deterministic tokenization from the record seed) so the
pipeline is end-to-end runnable; in addition every transform reports its
*virtual cost* in normalized cpu-units — the quantity the AutoScaler
provisions for (Fig. 5's heterogeneity), so benchmarks on this 1-core
container can reason about fleet sizing without wall-clock noise.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from repro.core.resilience import CorruptSampleError


@dataclasses.dataclass
class Sample:
    sample_id: str
    source: str
    modality: str
    tokens: np.ndarray          # token ids (text part)
    image_tokens: int           # number of patch tokens (0 for text)
    virtual_cost: float         # normalized preprocessing cost units
    meta: dict

    @property
    def total_tokens(self) -> int:
        return int(len(self.tokens)) + int(self.image_tokens)


_MAX_TOKENS = 10_000_000


def validate_record(record: dict) -> None:
    """Integrity gate run before transforming a record.

    Raises CorruptSampleError on malformed records (bit-flipped counts,
    missing fields, chaos-injected ``_corrupt`` markers) so the loader can
    quarantine the sample in its dead-letter queue instead of crashing.
    """
    if record.get("_corrupt"):
        raise CorruptSampleError(
            f"corruption marker: {record.get('_corrupt')}")
    sid = record.get("sample_id")
    if not isinstance(sid, str) or not sid:
        raise CorruptSampleError(f"bad sample_id {sid!r}")
    for key in ("text_tokens", "image_tokens"):
        v = record.get(key)
        if not isinstance(v, (int, np.integer)) or isinstance(v, bool) \
                or v < 0 or v > _MAX_TOKENS:
            raise CorruptSampleError(f"bad {key}={v!r} in {sid}")
    cost = record.get("transform_cost")
    if not isinstance(cost, (int, float)) or not math.isfinite(cost) \
            or cost < 0:
        raise CorruptSampleError(f"bad transform_cost={cost!r} in {sid}")
    if "seed" not in record:
        raise CorruptSampleError(f"missing seed in {sid}")


def transform_record(record: dict, source: str, vocab_size: int = 50_000,
                     work_scale: float = 0.0) -> Sample:
    """Tokenize / decode one record.

    ``work_scale`` > 0 burns proportional real CPU (for wall-clock
    experiments); the default keeps transforms cheap and reports virtual
    cost only.
    """
    n_text = int(record["text_tokens"])
    n_img = int(record["image_tokens"])
    rng = np.random.default_rng(record["seed"])
    tokens = rng.integers(1, vocab_size, size=n_text, dtype=np.int32)
    cost = float(record["transform_cost"]) * (n_text + n_img)
    if work_scale > 0:  # simulate heavyweight decode (JPEG/keyframe)
        k = int(work_scale * cost)
        if k:
            np.square(np.arange(min(k, 200_000), dtype=np.float64)).sum()
    return Sample(
        sample_id=record["sample_id"], source=source,
        modality=record["modality"], tokens=tokens, image_tokens=n_img,
        virtual_cost=cost,
        meta={"text_tokens": n_text, "image_tokens": n_img},
    )


def record_metadata(record: dict, source: str) -> dict:
    """Lightweight metadata (what Source Loaders report to the Planner —
    DGraph nodes carry this, never payloads)."""
    return {
        "sample_id": record["sample_id"],
        "source": source,
        "modality": record["modality"],
        "text_tokens": int(record["text_tokens"]),
        "image_tokens": int(record["image_tokens"]),
        "transform_cost": float(record["transform_cost"]),
    }
