"""Sample transformations (the P term of the loader cost tuple (P, T, M)).

Each transform converts a raw storage record into a training-ready sample:
token ids (text) and patch-embedding token counts (image/video/audio).
Real work is done (deterministic tokenization from the record seed) so the
pipeline is end-to-end runnable; in addition every transform reports its
*virtual cost* in normalized cpu-units — the quantity the AutoScaler
provisions for (Fig. 5's heterogeneity), so benchmarks on this 1-core
container can reason about fleet sizing without wall-clock noise.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Sample:
    sample_id: str
    source: str
    modality: str
    tokens: np.ndarray          # token ids (text part)
    image_tokens: int           # number of patch tokens (0 for text)
    virtual_cost: float         # normalized preprocessing cost units
    meta: dict

    @property
    def total_tokens(self) -> int:
        return int(len(self.tokens)) + int(self.image_tokens)


def transform_record(record: dict, source: str, vocab_size: int = 50_000,
                     work_scale: float = 0.0) -> Sample:
    """Tokenize / decode one record.

    ``work_scale`` > 0 burns proportional real CPU (for wall-clock
    experiments); the default keeps transforms cheap and reports virtual
    cost only.
    """
    n_text = int(record["text_tokens"])
    n_img = int(record["image_tokens"])
    rng = np.random.default_rng(record["seed"])
    tokens = rng.integers(1, vocab_size, size=n_text, dtype=np.int32)
    cost = float(record["transform_cost"]) * (n_text + n_img)
    if work_scale > 0:  # simulate heavyweight decode (JPEG/keyframe)
        k = int(work_scale * cost)
        if k:
            np.square(np.arange(min(k, 200_000), dtype=np.float64)).sum()
    return Sample(
        sample_id=record["sample_id"], source=source,
        modality=record["modality"], tokens=tokens, image_tokens=n_img,
        virtual_cost=cost,
        meta={"text_tokens": n_text, "image_tokens": n_img},
    )


def record_metadata(record: dict, source: str) -> dict:
    """Lightweight metadata (what Source Loaders report to the Planner —
    DGraph nodes carry this, never payloads)."""
    return {
        "sample_id": record["sample_id"],
        "source": source,
        "modality": record["modality"],
        "text_tokens": int(record["text_tokens"]),
        "image_tokens": int(record["image_tokens"]),
        "transform_cost": float(record["transform_cost"]),
    }
