"""Cost models registered via the ``cost()`` primitive (paper §4.2).

They estimate per-sample compute from METADATA ONLY (token counts), which
is what makes load-time balancing possible.  Coefficients derive from the
target architecture's config, so the same planner program serves every
assigned arch; for attention-free archs (rwkv6) the quadratic term is 0 and
balancing degenerates to token-count balancing (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class QuadraticCost:
    """flops(sample) ~ a * l + b * l^2  per packed subsequence of length l.

    a: per-token matmul work (6*N_active/layer-ish); b: attention term
    12 * L * d per token-pair (fwd 4*l^2*d per layer incl qk+av, x3 bwd).
    """
    a: float
    b: float
    name: str = "quadratic"

    def __call__(self, meta: dict) -> float:
        l = meta.get("text_tokens", 0) + meta.get("image_tokens", 0)
        return self.a * l + self.b * l * l


@dataclasses.dataclass(frozen=True)
class LinearCost:
    a: float
    name: str = "linear"

    def __call__(self, meta: dict) -> float:
        l = meta.get("text_tokens", 0) + meta.get("image_tokens", 0)
        return self.a * l


@dataclasses.dataclass(frozen=True)
class EncoderCost:
    """Vision/audio encoder: quadratic in per-image patch count (NaViT
    packs images, so cost follows sum p_i^2 over images in the batch)."""
    a: float
    b: float
    name: str = "encoder"

    def __call__(self, meta: dict) -> float:
        p = meta.get("image_tokens", 0)
        return self.a * p + self.b * p * p


def active_params(cfg: ModelConfig) -> float:
    """Rough active-parameter count per token (backbone only)."""
    d, L = cfg.d_model, cfg.num_layers
    hd = cfg.resolved_head_dim()
    attn = d * (cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd) \
        + cfg.num_heads * hd * d
    if cfg.num_experts > 0:
        ffn = 3 * d * cfg.d_ff * cfg.experts_per_token
    else:
        ffn = 3 * d * cfg.d_ff
    return L * (attn + ffn)


def backbone_cost(cfg: ModelConfig) -> Callable[[dict], float]:
    """Default cost model for a backbone config (what `cost()` registers)."""
    n = active_params(cfg)
    if cfg.family == "ssm":               # attention-free: linear
        return LinearCost(a=6.0 * n)
    b = 12.0 * cfg.num_layers * cfg.d_model
    if cfg.family == "hybrid":            # attention on 1/attn_every layers
        b = 12.0 * (cfg.num_layers // max(cfg.attn_every, 1)) * cfg.d_model
    return QuadraticCost(a=6.0 * n, b=b)


def encoder_cost(num_layers: int, d_model: int) -> EncoderCost:
    n = num_layers * 12 * d_model * d_model  # dense ViT block params approx
    return EncoderCost(a=6.0 * n / max(d_model, 1), b=12.0 * num_layers
                       * d_model)


def microbatch_cost(segment_lengths: list[int], costfn) -> float:
    """Cost of one packed row = sum of per-segment costs (block-diagonal
    attention => no cross terms; this is exactly what the Pallas
    segment-kernel computes)."""
    return float(sum(costfn({"text_tokens": l}) for l in segment_lengths))
