"""Trace spans for the data plane (docs/TELEMETRY.md).

A ``Tracer`` emits NESTED spans with monotonic timestamps: each thread
keeps its own open-span stack, so spans opened on one mailbox thread
nest under that thread's enclosing span and the resulting forest is
well-nested per thread (the invariant the property suite checks).
Finished spans land in a bounded deque (oldest evicted, eviction
counted) and are exported as a Chrome-trace timeline or a canonical
(timestamp-stripped) tree for golden tests — see ``repro.telemetry
.export``.

Attribution convention: every span carries the actor/source/step labels
relevant at its layer (``actor.call`` -> actor+method, ``loader.*`` ->
source, ``planner.*``/``constructor.*`` -> step/bucket), and chaos-
injected faults stamp a ``fault=<kind>`` attribute so soak tests can
assert every injected fault was OBSERVED, not merely scheduled.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Optional


class Span:
    """One finished or in-flight span.  Mutate attrs via ``set_attr``."""

    __slots__ = ("span_id", "parent_id", "name", "attrs", "thread",
                 "start", "end")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 attrs: dict, thread: str, start: float):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.attrs = attrs
        self.thread = thread
        self.start = start
        self.end: Optional[float] = None

    def set_attr(self, key: str, value) -> None:
        self.attrs[key] = value

    def stamp_fault(self, kind: str) -> None:
        """Mark this span as carrying an injected fault."""
        self.attrs["fault"] = kind

    @property
    def duration(self) -> float:
        return (self.end - self.start) if self.end is not None else 0.0

    def as_dict(self) -> dict:
        return {"span_id": self.span_id, "parent_id": self.parent_id,
                "name": self.name, "attrs": dict(self.attrs),
                "thread": self.thread, "start": self.start,
                "end": self.end}

    def __repr__(self) -> str:
        return f"Span({self.name!r}, id={self.span_id}, " \
               f"parent={self.parent_id}, attrs={self.attrs})"


class _NullSpan:
    """Shared no-op stand-in when telemetry is disabled.  Works both as a
    context manager and as the span it would yield."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set_attr(self, key: str, value) -> None:
        pass

    def stamp_fault(self, kind: str) -> None:
        pass


NULL_SPAN = _NullSpan()


class _SpanContext:
    __slots__ = ("_tracer", "_name", "_attrs", "_span")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None

    def __enter__(self) -> Span:
        self._span = self._tracer._open(self._name, self._attrs)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer._close(self._span, exc_type)
        return False


class Tracer:
    """Thread-aware span emitter with a bounded finished-span buffer."""

    def __init__(self, max_spans: int = 65536,
                 clock=time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self._max_spans = max(int(max_spans), 1)
        self._finished: deque = deque()
        self._local = threading.local()
        self._ids = itertools.count(1)
        self.dropped = 0        # finished spans evicted by the bound

    # -- span lifecycle ----------------------------------------------------
    def span(self, name: str, **attrs) -> _SpanContext:
        """Open a span as a context manager:

            with tracer.span("loader.refill", source="coyo_000") as sp:
                sp.set_attr("records", n)
        """
        return _SpanContext(self, name, attrs)

    def current(self) -> Optional[Span]:
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    def _open(self, name: str, attrs: dict) -> Span:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        parent = stack[-1].span_id if stack else None
        span = Span(next(self._ids), parent, name, attrs,
                    threading.current_thread().name, self._clock())
        stack.append(span)
        return span

    def _close(self, span: Optional[Span], exc_type) -> None:
        if span is None:
            return
        span.end = self._clock()
        if exc_type is not None:
            span.attrs.setdefault("error", exc_type.__name__)
        stack = getattr(self._local, "stack", None)
        if stack and stack[-1] is span:
            stack.pop()
        elif stack and span in stack:    # defensive: out-of-order close
            stack.remove(span)
        with self._lock:
            self._finished.append(span)
            while len(self._finished) > self._max_spans:
                self._finished.popleft()
                self.dropped += 1

    # -- export ------------------------------------------------------------
    def finished(self) -> list[Span]:
        """Finished spans in close order (children before parents)."""
        with self._lock:
            return list(self._finished)

    def find(self, name: Optional[str] = None, **attrs) -> list[Span]:
        """Finished spans matching a name and/or attr subset."""
        out = []
        for s in self.finished():
            if name is not None and s.name != name:
                continue
            if all(s.attrs.get(k) == v for k, v in attrs.items()):
                out.append(s)
        return out

    def clear(self) -> None:
        with self._lock:
            self._finished.clear()
            self.dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._finished)
