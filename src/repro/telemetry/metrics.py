"""Metric primitives for the unified telemetry plane (docs/TELEMETRY.md).

Three instrument kinds behind one thread-safe ``MetricsRegistry``:

  * Counter   — monotone float accumulator.  ``merge`` is associative
                (plain addition), so per-shard registries can be folded
                in any order.
  * Gauge     — last-write-wins sample of a level (queue depth, breaker
                state, token imbalance).
  * Histogram — streaming distribution with a BOUNDED reservoir
                (Algorithm R with a seeded RNG, so two same-seed runs
                keep identical reservoirs).  Quantiles are nearest-rank
                over the sorted reservoir, hence monotone in q — the
                invariant the property suite asserts.

Zero hard dependencies; safe to import from every layer (core, chaos,
analysis) without cycles.
"""
from __future__ import annotations

import math
import random
import threading
from typing import Iterable, Optional

DEFAULT_RESERVOIR = 512


def label_key(labels: dict) -> tuple:
    """Canonical hashable identity for a label set (values stringified)."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def render_key(name: str, key: tuple) -> str:
    """Prometheus-style series name: ``name{a="x",b="y"}``."""
    if not key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing accumulator (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0.0):
        self._lock = threading.Lock()
        self._value = float(value)

    def inc(self, value: float = 1.0) -> None:
        if value < 0:
            raise ValueError(f"counter increment must be >= 0, got {value}")
        with self._lock:
            self._value += value

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def merge(self, other: "Counter") -> "Counter":
        """Fold two counters into a new one.  Addition is associative and
        commutative, so any merge tree yields the same total."""
        return Counter(self.value + other.value)


class Gauge:
    """Last-write-wins level sample (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0.0):
        self._lock = threading.Lock()
        self._value = float(value)

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, delta: float) -> None:
        with self._lock:
            self._value += float(delta)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Streaming distribution with a bounded reservoir (thread-safe).

    Keeps exact count/sum/min/max plus a ``capacity``-bounded uniform
    sample of observations (Algorithm R, seeded for reproducibility).
    Quantiles are nearest-rank over the sorted reservoir: the index is a
    nondecreasing function of q, so quantiles are monotone by
    construction.
    """

    __slots__ = ("_lock", "capacity", "_reservoir", "_count", "_sum",
                 "_min", "_max", "_rng")

    def __init__(self, capacity: int = DEFAULT_RESERVOIR, seed: int = 0):
        self._lock = threading.Lock()
        self.capacity = max(int(capacity), 1)
        self._reservoir: list[float] = []
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._rng = random.Random(seed)

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if len(self._reservoir) < self.capacity:
                self._reservoir.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < self.capacity:
                    self._reservoir[j] = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        with self._lock:
            data = sorted(self._reservoir)
        if not data:
            return math.nan
        q = min(max(float(q), 0.0), 1.0)
        idx = min(len(data) - 1, max(0, math.ceil(q * len(data)) - 1))
        return data[idx]

    def quantiles(self, qs: Iterable[float]) -> list[float]:
        with self._lock:
            data = sorted(self._reservoir)
        out = []
        for q in qs:
            if not data:
                out.append(math.nan)
                continue
            q = min(max(float(q), 0.0), 1.0)
            idx = min(len(data) - 1, max(0, math.ceil(q * len(data)) - 1))
            out.append(data[idx])
        return out

    def snapshot(self) -> dict:
        with self._lock:
            data = sorted(self._reservoir)
            count, total = self._count, self._sum
            lo = self._min if count else math.nan
            hi = self._max if count else math.nan

        def _q(q: float) -> float:
            if not data:
                return math.nan
            return data[min(len(data) - 1, max(0, math.ceil(q * len(data)) - 1))]

        return {"count": count, "sum": total, "min": lo, "max": hi,
                "p50": _q(0.5), "p90": _q(0.9), "p99": _q(0.99)}


class MetricsRegistry:
    """Get-or-create home for every metric series (thread-safe).

    Series identity is ``(name, sorted label items)``; label values are
    stringified so ``rank=0`` and ``rank="0"`` are the same series.
    """

    def __init__(self, seed: int = 0,
                 histogram_capacity: int = DEFAULT_RESERVOIR):
        self._lock = threading.Lock()
        self.seed = seed
        self.histogram_capacity = histogram_capacity
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        k = (name, label_key(labels))
        with self._lock:
            c = self._counters.get(k)
            if c is None:
                c = self._counters[k] = Counter()
            return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = (name, label_key(labels))
        with self._lock:
            g = self._gauges.get(k)
            if g is None:
                g = self._gauges[k] = Gauge()
            return g

    def histogram(self, name: str, **labels) -> Histogram:
        k = (name, label_key(labels))
        with self._lock:
            h = self._histograms.get(k)
            if h is None:
                h = self._histograms[k] = Histogram(
                    self.histogram_capacity,
                    seed=hash((self.seed,) + k) & 0x7FFFFFFF)
            return h

    # -- convenience writers ----------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        self.counter(name, **labels).inc(value)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self.gauge(name, **labels).set(value)

    def observe(self, name: str, value: float, **labels) -> None:
        self.histogram(name, **labels).observe(value)

    # -- readers -----------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        with self._lock:
            c = self._counters.get((name, label_key(labels)))
        return c.value if c is not None else 0.0

    def counter_total(self, name: str) -> float:
        """Sum of one counter name across all label sets."""
        with self._lock:
            items = [c for (n, _), c in self._counters.items() if n == name]
        return sum(c.value for c in items)

    def gauge_value(self, name: str, default: float = math.nan,
                    **labels) -> float:
        with self._lock:
            g = self._gauges.get((name, label_key(labels)))
        return g.value if g is not None else default

    def series(self) -> dict:
        """Raw (kind -> {(name, labelkey) -> metric}) view; internal."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "histograms": dict(self._histograms)}

    def snapshot(self) -> dict:
        """JSON-able dump: rendered series name -> value(s)."""
        s = self.series()
        return {
            "counters": {render_key(n, k): c.value
                         for (n, k), c in sorted(s["counters"].items())},
            "gauges": {render_key(n, k): g.value
                       for (n, k), g in sorted(s["gauges"].items())},
            "histograms": {render_key(n, k): h.snapshot()
                           for (n, k), h in sorted(s["histograms"].items())},
        }

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry into a NEW one: counters add, gauges take
        the other side's sample when present (latest wins), histogram
        moments add with reservoirs concatenated up to capacity."""
        out = MetricsRegistry(seed=self.seed,
                              histogram_capacity=self.histogram_capacity)
        mine, theirs = self.series(), other.series()
        for (n, k), c in mine["counters"].items():
            out._counters[(n, k)] = Counter(c.value)
        for (n, k), c in theirs["counters"].items():
            prev = out._counters.get((n, k))
            out._counters[(n, k)] = c.merge(prev) if prev else Counter(c.value)
        for src in (mine["gauges"], theirs["gauges"]):
            for (n, k), g in src.items():
                out._gauges[(n, k)] = Gauge(g.value)
        for src in (mine["histograms"], theirs["histograms"]):
            for (n, k), h in src.items():
                dst = out._histograms.get((n, k))
                if dst is None:
                    dst = out._histograms[(n, k)] = Histogram(
                        self.histogram_capacity)
                with h._lock:
                    res, cnt, tot = list(h._reservoir), h._count, h._sum
                    lo, hi = h._min, h._max
                dst._count += cnt
                dst._sum += tot
                dst._min = min(dst._min, lo)
                dst._max = max(dst._max, hi)
                room = dst.capacity - len(dst._reservoir)
                dst._reservoir.extend(res[:room])
        return out
