"""Export surfaces for the telemetry plane (docs/TELEMETRY.md).

  * ``render_prometheus`` — text exposition of a MetricsRegistry
    (counters/gauges as-is, histograms as summaries with quantiles).
  * ``chrome_trace`` / ``write_chrome_trace`` — span timeline in the
    Chrome ``chrome://tracing`` / Perfetto JSON format.
  * ``canonical_spans`` — deterministic, timestamp-stripped span TREE
    for golden-trace regression tests: ids, timestamps, durations and
    thread names are dropped; attrs survive (minus an optional strip
    set) so the tree captures *what happened in what order under what*,
    not *when*.
"""
from __future__ import annotations

import json
import math
from typing import Iterable, Optional

from repro.telemetry.metrics import MetricsRegistry, render_key
from repro.telemetry.tracing import Span, Tracer


def _sanitize(value):
    if isinstance(value, bool):
        return str(value)
    if isinstance(value, (int, float, str)) or value is None:
        return value
    return str(value)


# -- Prometheus text exposition -------------------------------------------
def render_prometheus(registry: MetricsRegistry,
                      namespace: str = "repro") -> str:
    """Prometheus-style text dump.  Histograms are rendered as summaries
    (``{quantile="..."}`` series plus ``_count`` / ``_sum``)."""
    s = registry.series()
    lines: list[str] = []
    seen_types: set[str] = set()

    def _type_line(name: str, kind: str):
        if name not in seen_types:
            seen_types.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for (name, key), c in sorted(s["counters"].items()):
        full = f"{namespace}_{name}"
        _type_line(full, "counter")
        lines.append(f"{render_key(full, key)} {c.value:.10g}")
    for (name, key), g in sorted(s["gauges"].items()):
        full = f"{namespace}_{name}"
        _type_line(full, "gauge")
        lines.append(f"{render_key(full, key)} {g.value:.10g}")
    for (name, key), h in sorted(s["histograms"].items()):
        full = f"{namespace}_{name}"
        _type_line(full, "summary")
        snap = h.snapshot()
        for q, label in ((snap["p50"], "0.5"), (snap["p90"], "0.9"),
                         (snap["p99"], "0.99")):
            if not math.isnan(q):
                qkey = key + (("quantile", label),)
                lines.append(f"{render_key(full, tuple(sorted(qkey)))} "
                             f"{q:.10g}")
        lines.append(f"{render_key(full + '_count', key)} {snap['count']}")
        lines.append(f"{render_key(full + '_sum', key)} "
                     f"{snap['sum']:.10g}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict[str, float]:
    """Minimal parser for the exposition above (tests round-trip on it)."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        series, value = line.rsplit(" ", 1)
        out[series] = float(value)
    return out


# -- Chrome trace ----------------------------------------------------------
def _spans_of(source) -> list[Span]:
    if isinstance(source, Tracer):
        return source.finished()
    return list(source)


def chrome_trace(source) -> dict:
    """Complete-event ("ph": "X") Chrome trace; times in microseconds."""
    spans = _spans_of(source)
    tid_of: dict[str, int] = {}
    events = []
    for s in sorted(spans, key=lambda s: (s.start, s.span_id)):
        tid = tid_of.setdefault(s.thread, len(tid_of) + 1)
        events.append({
            "name": s.name, "cat": "repro", "ph": "X",
            "ts": s.start * 1e6, "dur": s.duration * 1e6,
            "pid": 1, "tid": tid,
            "args": {k: _sanitize(v) for k, v in s.attrs.items()},
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
             "args": {"name": thread}}
            for thread, tid in sorted(tid_of.items(), key=lambda kv: kv[1])]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, source) -> str:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome_trace(source), f)
    return path


# -- canonical span tree (golden tests) ------------------------------------
def canonical_spans(source, strip_attrs: Iterable[str] = ()) -> list[dict]:
    """Timestamp-stripped span forest, children in start order.

    A span whose parent never finished (still open at export) is
    promoted to a root — the tree must be buildable from whatever the
    bounded buffer holds.
    """
    spans = _spans_of(source)
    strip = set(strip_attrs) | {"error"}
    by_id = {s.span_id: s for s in spans}
    children: dict[Optional[int], list[Span]] = {}
    for s in spans:
        parent = s.parent_id if s.parent_id in by_id else None
        children.setdefault(parent, []).append(s)

    def _node(s: Span) -> dict:
        node = {"name": s.name,
                "attrs": {k: _sanitize(v)
                          for k, v in sorted(s.attrs.items())
                          if k not in strip}}
        kids = sorted(children.get(s.span_id, []),
                      key=lambda c: (c.start, c.span_id))
        if kids:
            node["children"] = [_node(c) for c in kids]
        return node

    roots = sorted(children.get(None, []), key=lambda s: (s.start, s.span_id))
    return [_node(s) for s in roots]
