# Unified telemetry plane (docs/TELEMETRY.md): metrics + trace spans +
# export surfaces.  Dependency-free — importable from core, chaos, and
# analysis without cycles.
from repro.telemetry.export import (  # noqa: F401
    canonical_spans, chrome_trace, parse_prometheus, render_prometheus,
    write_chrome_trace,
)
from repro.telemetry.metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, label_key, render_key,
)
from repro.telemetry.plane import (  # noqa: F401
    NULL_TELEMETRY, Telemetry, ensure_telemetry,
)
from repro.telemetry.tracing import NULL_SPAN, Span, Tracer  # noqa: F401
