"""The telemetry plane: one MetricsRegistry + one Tracer per Overlord.

``Telemetry`` is the object the whole data plane shares: the Overlord
creates one, hands it to the ActorRuntime and to every actor it spawns,
and every instrumentation site goes through it.  With ``enabled=False``
all writers are no-ops and ``span()`` returns a shared null context
manager, so the disabled overhead is one attribute check per site (the
<= 5% budget benchmarks/orchestration.py:run_telemetry_overhead gates).

``NULL_TELEMETRY`` / ``ensure_telemetry`` give components a uniform
"maybe instrumented" dependency without None checks at every site.
"""
from __future__ import annotations

from typing import Optional

from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.tracing import NULL_SPAN, Tracer


class Telemetry:
    def __init__(self, enabled: bool = True, max_spans: int = 65536,
                 seed: int = 0):
        self.enabled = enabled
        self.registry = MetricsRegistry(seed=seed)
        self.tracer = Tracer(max_spans=max_spans)

    # -- tracing -----------------------------------------------------------
    def span(self, name: str, **attrs):
        if not self.enabled:
            return NULL_SPAN
        return self.tracer.span(name, **attrs)

    # -- metrics -----------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        if self.enabled:
            self.registry.inc(name, value, **labels)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        if self.enabled:
            self.registry.set_gauge(name, value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        if self.enabled:
            self.registry.observe(name, value, **labels)

    def snapshot(self) -> dict:
        return self.registry.snapshot()


#: Shared disabled instance — writers are no-ops, never read from.
NULL_TELEMETRY = Telemetry(enabled=False, max_spans=1)


def ensure_telemetry(tel: Optional[Telemetry]) -> Telemetry:
    """Uniform dependency: a real plane when given one, else the null."""
    return tel if tel is not None else NULL_TELEMETRY
