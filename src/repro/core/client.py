"""Trainer-side client with a prefetch ring buffer (§6.1, Fig. 16).

One client per trainer rank.  A background thread keeps ``prefetch``
steps buffered ahead of the training loop; ``get(step)`` blocks only on
true underflow and records the stall — the quantity the fault-tolerance
benchmark plots (data-fetch-latency spikes).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class TrainerClient:
    def __init__(self, rank: int, fetch: Callable[[int, int], Optional[dict]],
                 prefetch: int = 2, poll_interval: float = 0.002,
                 start_step: int = 0):
        self.rank = rank
        self._fetch = fetch            # (step, rank) -> view dict | None
        self.prefetch = prefetch
        self.poll = poll_interval
        self._buf: dict[int, dict] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # start_step > 0 on job resume: steps before it were consumed by
        # the previous incarnation and must not be prefetched again
        self._next_wanted = start_step
        self._stop = threading.Event()
        self.stall_log: list[tuple[int, float]] = []   # (step, stall_s)
        self.fetch_log: list[tuple[int, float]] = []   # (step, fetch_s)
        self._thread = threading.Thread(
            target=self._loop, name=f"client-{rank}", daemon=True)
        self._thread.start()

    def _loop(self):
        while not self._stop.is_set():
            with self._lock:
                wanted = [s for s in range(self._next_wanted,
                                           self._next_wanted + self.prefetch)
                          if s not in self._buf]
            if not wanted:
                time.sleep(self.poll)
                continue
            for s in wanted:
                t0 = time.time()
                try:
                    view = self._fetch(s, self.rank)
                except Exception:
                    view = None
                if view is not None:
                    with self._cv:
                        self._buf[s] = view
                        self.fetch_log.append((s, time.time() - t0))
                        self._cv.notify_all()
                else:
                    time.sleep(self.poll)
                    break

    def get(self, step: int, timeout: float = 30.0) -> dict:
        t0 = time.time()
        with self._cv:
            self._next_wanted = max(self._next_wanted, step)
            while step not in self._buf:
                if not self._cv.wait(timeout=self.poll * 10):
                    pass
                if time.time() - t0 > timeout:
                    raise TimeoutError(
                        f"client {self.rank}: step {step} not delivered")
            view = self._buf.pop(step)
            self._next_wanted = step + 1
        stall = time.time() - t0
        self.stall_log.append((step, stall))
        return view

    def buffered(self) -> int:
        with self._lock:
            return len(self._buf)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
