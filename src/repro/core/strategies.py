"""Ready-made orchestration strategies (paper Fig. 9 + §7 baselines).

A strategy is ``fn(ctx: Orchestration, **params) -> LoadingPlan``.  The
three evaluation arms of the paper:

  * vanilla          — no scheduling (round-robin buckets)
  * backbone_balance — inter-microbatch balancing on the LLM backbone only
  * hybrid_balance   — interleaved encoder (image) balancing + backbone
                       balancing combined (the VLM strategy)
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.mixing import MixSchedule
from repro.core.primitives import LoadingPlan, Orchestration


def vanilla(ctx: Orchestration, *, schedule: MixSchedule, total: int,
            n_bins: int = 1, costfn=None, axis: str = "DP") -> LoadingPlan:
    """No load balancing: FIFO round-robin over buckets (colocated-loader
    behaviour).  Cost annotations are kept so diagnostics can report the
    imbalance that WOULD have been avoided."""
    ctx.mix(schedule, total)
    g = ctx.dgraph("main")
    nb = ctx.distribute(axis)
    ctx.microbatches(n_bins)
    if costfn is not None:
        ctx.cost(costfn, g)
    g.assign_buckets([i % nb for i in range(len(g.nodes))])
    for b, nodes in g.by_bucket().items():
        g.assign_bins(nodes, [i % ctx._n_bins for i in range(len(nodes))])
    from repro.core.balance import bin_loads, imbalance
    loads = bin_loads(g.costs(), [n.bucket for n in g.nodes], nb)
    ctx._diag["balance:main"] = {
        "bucket_loads": loads, "imbalance": imbalance(loads),
        "method": "none", "level": "none"}
    return ctx.plan(g)


def backbone_balance(ctx: Orchestration, *, schedule: MixSchedule,
                     total: int, costfn, n_bins: int = 1,
                     method: str = "greedy_binpack",
                     axis: str = "DP",
                     broadcast: tuple = ("TP",)) -> LoadingPlan:
    """Fig. 9 LLMBalance: distribute along DP, balance packed-sequence cost
    across DP buckets and microbatch bins."""
    ctx.mix(schedule, total)
    g = ctx.dgraph("main")
    ctx.distribute(axis)
    ctx.microbatches(n_bins)
    ctx.cost(costfn, g)
    ctx.balance(method, level="inter", graph=g)
    if broadcast:
        ctx.broadcast_at(*broadcast)
    return ctx.plan(g)


def hybrid_balance(ctx: Orchestration, *, schedule: MixSchedule,
                   total: int, backbone_costfn, encoder_costfn,
                   n_bins: int = 1, method: str = "greedy_binpack",
                   encoder_axis: str = "WORLD",
                   axis: str = "DP",
                   broadcast: tuple = ("TP",)) -> LoadingPlan:
    """Fig. 9 VLM strategy: the image DGraph is derived from the SAME
    buffer with different metadata; images are balanced across the
    encoder's (world-wide DP) consumers first, then the backbone balance
    runs over complete sequences with buckets preserved for image-heavy
    samples (inter-module balancing)."""
    from repro.core.balance import (
        bin_loads, imbalance, multi_greedy_binpack,
    )

    ctx.mix(schedule, total)
    g = ctx.dgraph("main")
    # image DGraph: same buffer, different metadata (paper Fig. 9)
    img = g.derive("image", lambda m: m.get("image_tokens", 0) > 0)
    ctx._graphs["image"] = img
    ctx.cost(encoder_costfn, img)

    nb = ctx.distribute(axis)
    ctx.microbatches(n_bins)
    ctx.cost(backbone_costfn, g)

    # inter-module balance: each sample carries (encoder, backbone) costs;
    # minimize the worst per-module bucket load simultaneously (modules are
    # colocated, so both must be flat).
    enc_costs = {id(n): n.cost for n in img.nodes}
    vectors = [(enc_costs.get(id(n), 0.0), backbone_costfn(n.meta))
               for n in g.nodes]
    g.with_cost(backbone_costfn)
    g.assign_buckets(multi_greedy_binpack(vectors, nb))
    idx = {id(n): i for i, n in enumerate(g.nodes)}
    for b, nodes in g.by_bucket().items():
        sub = multi_greedy_binpack(
            [vectors[idx[id(n)]] for n in nodes], ctx._n_bins)
        g.assign_bins(nodes, sub)

    bb_loads = bin_loads([v[1] for v in vectors],
                         [n.bucket for n in g.nodes], nb)
    enc_loads = bin_loads([v[0] for v in vectors],
                          [n.bucket for n in g.nodes], nb)
    ctx._diag["balance:main"] = {
        "bucket_loads": bb_loads, "imbalance": imbalance(bb_loads),
        "method": "multi_greedy", "level": "inter-module"}
    ctx._diag["balance:image"] = {
        "bucket_loads": enc_loads, "imbalance": imbalance(enc_loads),
        "method": "multi_greedy", "level": "inter-module"}
    if broadcast:
        ctx.broadcast_at(*broadcast)
    return ctx.plan(g)


STRATEGIES: dict[str, Callable] = {
    "vanilla": vanilla,
    "backbone_balance": backbone_balance,
    "hybrid_balance": hybrid_balance,
}
