"""Lightweight actor runtime (the paper builds on Ray; DESIGN.md §2).

Semantics kept from the paper's needs: named actors with isolated state and
a mailbox thread, async ``cast`` / sync ``call`` with futures, abrupt
``kill`` (simulated crash: pending mail dropped, no cleanup), heartbeat
supervision with failure callbacks, and memory introspection for the
resource benchmarks.  On a real cluster this class is the only thing to
swap for Ray/K8s actors.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from concurrent.futures import Future, InvalidStateError
from typing import Any, Callable, Optional

from repro.core.resilience import RetryPolicy
from repro.telemetry import Telemetry, ensure_telemetry


class ActorDied(RuntimeError):
    pass


class Actor:
    """Base class.  Subclasses define plain methods; they run on the actor
    thread, one message at a time (no locks needed on actor state)."""

    name: str = "actor"

    # lifecycle hooks ---------------------------------------------------
    def on_start(self) -> None: ...
    def on_stop(self) -> None: ...

    # checkpoint hooks (fault.py) --------------------------------------
    def checkpoint_state(self) -> Any:
        return None

    def restore_state(self, state: Any) -> None: ...

    # resource accounting ----------------------------------------------
    def memory_bytes(self) -> int:
        return 0


class _Mail:
    __slots__ = ("method", "args", "kwargs", "future")

    def __init__(self, method, args, kwargs, future):
        self.method = method
        self.args = args
        self.kwargs = kwargs
        self.future = future


class ActorHandle:
    def __init__(self, name: str, actor: Actor, runtime: "ActorRuntime"):
        self.name = name
        self._actor = actor
        self._runtime = runtime
        self._telemetry = runtime.telemetry
        self._mailbox: queue.Queue = queue.Queue()
        self._alive = threading.Event()
        self._killed = threading.Event()
        self._hung = False
        self._current_mail: Optional[_Mail] = None
        self._thread = threading.Thread(
            target=self._loop, name=f"actor:{name}", daemon=True)

    # -- lifecycle -------------------------------------------------------
    def _start(self):
        self._alive.set()
        self._thread.start()

    def _loop(self):
        try:
            self._actor.on_start()
        except Exception:
            traceback.print_exc()
            self._alive.clear()
            return
        while not self._killed.is_set():
            try:
                mail = self._mailbox.get(timeout=0.05)
            except queue.Empty:
                continue
            if mail is None:
                break
            if self._killed.is_set():
                self._fail_mail(mail)
                break
            self._current_mail = mail
            try:
                fn = getattr(self._actor, mail.method)
                # span on the mailbox thread: the actor-side timeline in
                # the Chrome trace (caller side is the actor.call span)
                with self._telemetry.span("actor.exec", actor=self.name,
                                          method=mail.method):
                    result = fn(*mail.args, **mail.kwargs)
                if mail.future is not None:
                    try:
                        mail.future.set_result(result)
                    except InvalidStateError:
                        pass   # kill() failed this future while in flight
            except Exception as e:  # actor method raised
                if mail.future is not None:
                    try:
                        mail.future.set_exception(e)
                    except InvalidStateError:
                        pass
                else:
                    traceback.print_exc()
            finally:
                self._current_mail = None
        try:
            if not self._killed.is_set():
                self._actor.on_stop()
        finally:
            self._alive.clear()
            self._drain_mailbox()

    def _fail_mail(self, mail):
        if mail is not None and mail.future is not None \
                and not mail.future.done():
            try:
                mail.future.set_exception(ActorDied(
                    f"actor {self.name} died with mail pending"))
            except InvalidStateError:
                pass

    def _drain_mailbox(self):
        """A dead actor must not leave callers blocked on futures."""
        while True:
            try:
                self._fail_mail(self._mailbox.get_nowait())
            except queue.Empty:
                return

    @property
    def alive(self) -> bool:
        # killed counts as dead immediately: the actor thread may take a
        # while to notice (it could be wedged mid-method), and callers
        # probing alive must not enqueue into a mailbox nobody will drain
        return self._alive.is_set() and not self._killed.is_set()

    @property
    def hung(self) -> bool:
        """True when stop() timed out joining the actor thread."""
        return self._hung

    def kill(self):
        """Simulated crash: no cleanup, pending mail dropped.  In-flight
        and queued calls fail immediately with ActorDied instead of
        blocking until their timeout."""
        self._killed.set()
        self._fail_mail(self._current_mail)
        self._drain_mailbox()

    def stop(self, timeout: float = 5.0):
        """Graceful stop: drain then on_stop().  A join timeout means the
        actor thread is wedged: mark the handle dead (and hung) so the
        runtime's failure callbacks fire instead of silently leaking a
        zombie."""
        self._mailbox.put(None)
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            self._hung = True
            self._killed.set()
            self._alive.clear()
            self._fail_mail(self._current_mail)
            self._drain_mailbox()
        else:
            self._alive.clear()

    # -- messaging -------------------------------------------------------
    def call(self, method: str, *args, timeout: Optional[float] = 30.0,
             retry: Optional[RetryPolicy] = None, **kwargs):
        """Synchronous call.  With ``retry`` set, retryable failures
        (timeouts, transient IO errors raised by the method) are retried
        with the policy's backoff; ActorDied is NOT retryable here — a
        dead handle stays dead, use ActorRuntime.call_with_retry to chase
        supervised respawns by name."""
        tel = self._telemetry
        if not tel.enabled:
            if retry is None:
                return self._call_once(method, args, kwargs, timeout)
            return retry.run(self._call_once, method, args, kwargs, timeout)
        t0 = time.perf_counter()
        with tel.span("actor.call", actor=self.name, method=method):
            tel.inc("actor_calls_total", 1.0, actor=self.name,
                    method=method)
            try:
                if retry is None:
                    result = self._call_once(method, args, kwargs, timeout)
                else:
                    result = retry.run(
                        self._call_once, method, args, kwargs, timeout,
                        on_retry=lambda attempt, exc: tel.inc(
                            "actor_retries_total", 1.0, actor=self.name,
                            method=method))
            except Exception:
                tel.inc("actor_call_failures_total", 1.0, actor=self.name,
                        method=method)
                raise
        tel.observe("actor_call_seconds", time.perf_counter() - t0,
                    method=method)
        return result

    def _call_once(self, method: str, args: tuple, kwargs: dict,
                   timeout: Optional[float]):
        if not self.alive:
            raise ActorDied(f"actor {self.name} is dead")
        fut: Future = Future()
        self._mailbox.put(_Mail(method, args, kwargs, fut))
        return fut.result(timeout=timeout)

    def call_async(self, method: str, *args, **kwargs) -> Future:
        if not self.alive:
            raise ActorDied(f"actor {self.name} is dead")
        tel = self._telemetry
        if tel.enabled:
            tel.inc("actor_async_calls_total", 1.0, actor=self.name,
                    method=method)
        fut: Future = Future()
        self._mailbox.put(_Mail(method, args, kwargs, fut))
        return fut

    def cast(self, method: str, *args, **kwargs) -> None:
        if not self.alive:
            raise ActorDied(f"actor {self.name} is dead")
        tel = self._telemetry
        if tel.enabled:
            tel.inc("actor_casts_total", 1.0, actor=self.name,
                    method=method)
        self._mailbox.put(_Mail(method, args, kwargs, None))

    # -- introspection ----------------------------------------------------
    def memory_bytes(self) -> int:
        if not self.alive:
            return 0
        try:
            return self.call("memory_bytes", timeout=10)
        except Exception:
            return 0

    @property
    def mailbox_depth(self) -> int:
        return self._mailbox.qsize()


class _FanCall:
    __slots__ = ("handle", "method", "args", "kwargs", "timeout", "retry",
                 "attempt", "future")

    def __init__(self, handle, method, args, kwargs, timeout, retry):
        self.handle = handle
        self.method = method
        self.args = args
        self.kwargs = kwargs
        self.timeout = timeout
        self.retry = retry
        self.attempt = 0
        self.future: Optional[Future] = None


class FanOut:
    """One overlapped wave of ``call_async`` RPCs across many handles.

    ``submit()`` enqueues the call on every target's mailbox immediately
    (no round-trip); ``gather()`` then collects the futures, so the wave
    costs one max-latency instead of a sum of round-trips.  Per-call
    RetryPolicy semantics match ``ActorHandle.call``: a retryable failure
    (timeout, transient IO error raised by the method) re-submits THAT
    call with the policy's backoff; ``ActorDied`` stays terminal — a dead
    handle does not resurrect under the same object, so chasing respawns
    is ``ActorRuntime.call_with_retry``'s job.  Calls that still fail
    after retries land in ``failures`` keyed like their results would
    have been; ``gather()`` never raises.
    """

    def __init__(self, telemetry: Optional["Telemetry"] = None):
        self.telemetry = ensure_telemetry(telemetry)
        self._calls: "dict[Any, _FanCall]" = {}
        self.failures: "dict[Any, BaseException]" = {}

    def submit(self, key, handle: "ActorHandle", method: str, *args,
               timeout: Optional[float] = 30.0,
               retry: Optional[RetryPolicy] = None, **kwargs) -> None:
        call = _FanCall(handle, method, args, kwargs, timeout, retry)
        self._start(key, call)
        self._calls[key] = call

    def _start(self, key, call: _FanCall) -> None:
        call.attempt += 1
        try:
            call.future = call.handle.call_async(
                call.method, *call.args, **call.kwargs)
        except Exception as e:       # dead handle: fail at submit time
            call.future = None
            self.failures[key] = e

    def gather(self) -> dict:
        """Collect the wave.  Returns ``{key: result}`` for successes;
        failed calls (after per-call retries) are in ``failures``."""
        tel = self.telemetry
        results = {}
        with tel.span("actor.fanout", calls=len(self._calls)):
            for key, call in self._calls.items():
                while call.future is not None:
                    try:
                        results[key] = call.future.result(
                            timeout=call.timeout)
                        break
                    except Exception as e:
                        retry = call.retry
                        retryable = (retry is not None
                                     and retry.is_retryable(e)
                                     and call.attempt < retry.max_attempts)
                        if not retryable:
                            self.failures[key] = e
                            tel.inc("fanout_call_failures_total", 1.0,
                                    method=call.method)
                            break
                        tel.inc("actor_retries_total", 1.0,
                                actor=call.handle.name, method=call.method)
                        time.sleep(retry.delay(call.attempt - 1))
                        self._start(key, call)
        self._calls.clear()
        return results


class ActorRuntime:
    """Spawns actors, supervises liveness, reports fleet memory."""

    def __init__(self, heartbeat_interval: float = 0.05,
                 telemetry: Optional[Telemetry] = None):
        self.telemetry = ensure_telemetry(telemetry)
        self._actors: dict[str, ActorHandle] = {}
        self._lock = threading.Lock()
        self._failure_cbs: list[Callable[[str, ActorHandle], None]] = []
        self._hb_interval = heartbeat_interval
        self._stop = threading.Event()
        self._reported_dead: set[str] = set()
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="actor-monitor", daemon=True)
        self._monitor.start()

    def spawn(self, name: str, actor: Actor) -> ActorHandle:
        actor.name = name
        handle = ActorHandle(name, actor, self)
        with self._lock:
            if name in self._actors and self._actors[name].alive:
                raise ValueError(f"actor {name!r} already running")
            self._actors[name] = handle
            self._reported_dead.discard(name)
        handle._start()
        return handle

    def get(self, name: str) -> ActorHandle:
        with self._lock:
            return self._actors[name]

    def reassign(self, old: str, new: str) -> ActorHandle:
        """Re-register a live actor under a new name (shadow promotion)."""
        with self._lock:
            h = self._actors.pop(old)
            h.name = new
            h._actor.name = new
            self._actors[new] = h
            self._reported_dead.discard(new)
            return h

    def on_failure(self, cb: Callable[[str, ActorHandle], None]):
        self._failure_cbs.append(cb)

    def call_with_retry(self, name: str, method: str, *args,
                        retry: Optional[RetryPolicy] = None,
                        timeout: Optional[float] = 30.0, **kwargs):
        """call() that re-resolves the handle by NAME on every attempt, so
        it rides through supervised respawns and shadow promotions (where
        the name is remapped to a fresh handle).  ActorDied and missing
        names are therefore retryable at this level."""
        if retry is None:
            retry = RetryPolicy(retryable=RetryPolicy().retryable
                                + (ActorDied, KeyError))
        elif not any(issubclass(ActorDied, r) for r in retry.retryable):
            retry = RetryPolicy(**{**retry.__dict__,
                                   "retryable": tuple(retry.retryable)
                                   + (ActorDied, KeyError)})

        def _attempt():
            return self.get(name).call(method, *args, timeout=timeout,
                                       **kwargs)
        return retry.run(_attempt)

    def _monitor_loop(self):
        while not self._stop.is_set():
            time.sleep(self._hb_interval)
            with self._lock:
                items = list(self._actors.items())
            for name, h in items:
                if not h.alive and h._killed.is_set() \
                        and name not in self._reported_dead:
                    self._reported_dead.add(name)
                    self.telemetry.inc("actor_deaths_total", 1.0,
                                       actor=name)
                    for cb in self._failure_cbs:
                        try:
                            cb(name, h)
                        except Exception:
                            traceback.print_exc()

    def actors(self) -> dict[str, ActorHandle]:
        with self._lock:
            return dict(self._actors)

    def memory_report(self) -> dict[str, int]:
        return {n: h.memory_bytes() for n, h in self.actors().items()
                if h.alive}

    def shutdown(self):
        self._stop.set()
        for h in self.actors().values():
            if h.alive:
                h.stop()

    def terminate(self):
        """Abrupt whole-runtime death (process-crash analog): every actor
        is killed with pending mail dropped, no on_stop, and — unlike
        individual kills — NO failure callbacks fire, because the
        supervisor died with the process.  Recovery must come from disk
        (Overlord.resume), not from in-process supervision."""
        self._failure_cbs.clear()
        self._stop.set()
        for h in self.actors().values():
            h.kill()
