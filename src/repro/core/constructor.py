"""DataConstructor actor: microbatch + parallelism transformations (§3).

One constructor per DP bucket (the data *sink* for every rank in that
bucket's parallelism group).  It aggregates Source Loader outputs for its
bucket, packs them into microbatches, and serves per-CLIENT views:
  * CP ranks get zig-zag sequence slices of the same batch,
  * PP>0 stages get metadata only,
  * TP>0 ranks get nothing when broadcast_at("TP") is active.
This is the parallelism-redundancy elimination of Figs. 6/14: each
distinct byte exists once per bucket, not once per rank.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.core.actors import Actor
from repro.core.placetree import ClientPlaceTree
from repro.data import packing
from repro.data.transforms import Sample
from repro.telemetry import Telemetry, ensure_telemetry


class DataConstructor(Actor):
    def __init__(self, bucket: int, tree: ClientPlaceTree, seq_len: int,
                 rows_per_microbatch: int, n_bins: int = 1,
                 queue_depth: int = 4, ledger=None,
                 telemetry: Optional[Telemetry] = None):
        self.bucket = bucket
        self.tree = tree
        self.seq_len = seq_len
        self.rows = rows_per_microbatch
        self.n_bins = n_bins
        self.queue_depth = queue_depth
        self.ledger = ledger
        self.telemetry = ensure_telemetry(telemetry)
        # step -> {"bins": [PackedBatch...], "meta": {...}}
        self._ready: dict[int, dict] = {}
        self._pending: dict[int, dict] = {}   # step -> bin -> [samples]
        self._expected: dict[int, dict] = {}  # step -> source -> count
        self._dropped = 0
        self._built_steps = 0

    # -- deposits from Source Loaders --------------------------------------
    def expect(self, step: int, per_source_counts: dict,
               n_bins: int) -> bool:
        """Open a step for deposits.  Returns False when the step is
        already assembled here — a replanning planner (post-recovery) must
        not overwrite a batch a client may have consumed (first plan
        wins)."""
        if step in self._ready:
            return False
        self._expected[step] = dict(per_source_counts)
        self.n_bins = n_bins
        self._pending.setdefault(step, {})
        if all(v <= 0 for v in self._expected[step].values()):
            # nothing routed to this bucket: assemble the empty step now
            # instead of wedging clients that wait on it forever
            self._assemble(step)
        return True

    def ingest(self, step: int, per_source: dict, n_bins: int) -> bool:
        """Batched ``expect`` + ``deposit`` in one RPC: ``per_source``
        maps source -> (samples, bins).  The planner's dispatch stage
        used to pay 1 + n_sources mailbox round-trips per constructor
        per step; this collapses them into one.  Returns False when the
        step is already assembled here (replan after recovery — first
        plan wins, exactly like ``expect``)."""
        counts = {src: len(samples)
                  for src, (samples, _bins) in per_source.items()}
        if not self.expect(step, counts or {"_": 0}, n_bins):
            return False
        for src, (samples, bins) in per_source.items():
            self.deposit(step, src, samples, bins)
        return True

    def deposit(self, step: int, source: str, samples: list[Sample],
                bins: list[int]):
        self.telemetry.inc("constructor_deposits_total", len(samples),
                           bucket=self.bucket, source=source)
        pend = self._pending.setdefault(step, {})
        for s, b in zip(samples, bins):
            pend.setdefault(b, []).append(s)
        exp = self._expected.get(step)
        if exp is not None:
            exp[source] = exp.get(source, 0) - len(samples)
            if all(v <= 0 for v in exp.values()):
                self._assemble(step)

    def _assemble(self, step: int):
        tel = self.telemetry
        with tel.span("constructor.assemble", bucket=self.bucket,
                      step=step):
            pend = self._pending.pop(step, {})
            self._expected.pop(step, None)
            bins = []
            for b in range(self.n_bins):
                samples = pend.get(b, [])
                batch = packing.pack_sequences(samples, self.seq_len,
                                               self.rows)
                packed_ids = {i for row in batch.doc_ids for i in row}
                for s in samples:
                    if s.sample_id not in packed_ids:
                        self._dropped += 1
                        tel.inc("constructor_dropped_total", 1.0,
                                bucket=self.bucket,
                                reason="packing_overflow")
                        if self.ledger is not None:
                            self.ledger.record_dropped(
                                step, s.sample_id, "packing_overflow")
                if tel.enabled:
                    tel.observe("constructor_bin_tokens",
                                int((batch.segment_ids > 0).sum()),
                                bucket=self.bucket)
                bins.append(batch)
            self._ready[step] = {"bins": bins}
            self._built_steps += 1
            # bound memory: drop oldest ready steps beyond queue depth
            while len(self._ready) > self.queue_depth:
                oldest = min(self._ready)
                if oldest == step:
                    break
                if self.ledger is not None:
                    for batch in self._ready[oldest]["bins"]:
                        for row in batch.doc_ids:
                            for sid in row:
                                self.ledger.record_dropped(
                                    oldest, sid, "queue_evicted")
                                tel.inc("constructor_dropped_total", 1.0,
                                        bucket=self.bucket,
                                        reason="queue_evicted")
                del self._ready[oldest]
        if tel.enabled:
            tel.set_gauge("constructor_ready_depth",
                          float(len(self._ready)), bucket=self.bucket)

    def ready_steps(self) -> list[int]:
        return sorted(self._ready)

    # -- client-facing fetch -------------------------------------------------
    def get_view(self, step: int, rank: int,
                 distribute_axis: str = "DP") -> Optional[dict]:
        """The parallelism transformation for one client."""
        if step not in self._ready:
            return None
        view = self.tree.client_view(rank, distribute_axis)
        bins = self._ready[step]["bins"]
        if view.role == "none":
            return {"role": "none", "step": step}
        if view.role == "metadata":
            return {"role": "metadata", "step": step,
                    "bins": [packing.metadata_only(b) for b in bins]}
        out_bins = []
        for b in bins:
            sliced = packing.cp_slice(b, view.cp_rank, view.cp_degree) \
                if view.cp_degree > 1 else b
            out_bins.append(sliced)
        return {"role": "data", "step": step, "bins": out_bins,
                "cp_rank": view.cp_rank}

    def pop_step(self, step: int):
        self._ready.pop(step, None)

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        return {"bucket": self.bucket, "ready": sorted(self._ready),
                "dropped": self._dropped, "built_steps": self._built_steps}

    def memory_bytes(self) -> int:
        total = 0
        for entry in self._ready.values():
            for b in entry["bins"]:
                total += b.tokens.nbytes + b.segment_ids.nbytes \
                    + b.positions.nbytes + b.labels.nbytes
        for pend in self._pending.values():
            for samples in pend.values():
                total += sum(s.tokens.nbytes + 200 for s in samples)
        return total

    def checkpoint_state(self) -> dict:
        # ``ready`` carries the in-flight window: steps assembled but not
        # yet consumed when the cut was taken.  A resumed job serves the
        # gap between the delivery frontier and the plan frontier from
        # these views — their samples were already popped from the loader
        # buffers, so they cannot be replanned, only restored.
        return {"bucket": self.bucket, "built_steps": self._built_steps,
                "dropped": self._dropped,
                "ready": {s: {"bins": list(e["bins"])}
                          for s, e in self._ready.items()}}

    def restore_state(self, state: dict):
        if state.get("bucket", self.bucket) != self.bucket:
            raise ValueError(
                f"checkpoint for bucket {state.get('bucket')} offered to "
                f"constructor bucket {self.bucket}")
        self._built_steps = state["built_steps"]
        self._dropped = state["dropped"]
        self._ready.update({int(s): {"bins": list(e["bins"])}
                            for s, e in state.get("ready", {}).items()})
