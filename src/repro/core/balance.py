"""Balancing methods for the ``balance()`` primitive (paper §4.2).

Both operate on (cost, item) pairs and return bin assignments minimizing
the max-bin cost (the straggler — what sets step time under quadratic
attention).  ``greedy_binpack`` is LPT (4/3-approx); ``karmarkar_karp``
is the multiway differencing method (better for few large bins).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional, Sequence


def greedy_binpack(costs: Sequence[float], n_bins: int) -> list[int]:
    """Longest-processing-time-first.  Returns bin index per item."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    order = sorted(range(len(costs)), key=lambda i: -costs[i])
    heap = [(0.0, b) for b in range(n_bins)]
    heapq.heapify(heap)
    assign = [0] * len(costs)
    for i in order:
        load, b = heapq.heappop(heap)
        assign[i] = b
        heapq.heappush(heap, (load + costs[i], b))
    return assign


def karmarkar_karp(costs: Sequence[float], n_bins: int) -> list[int]:
    """Multiway Karmarkar-Karp differencing.

    Maintains a heap of partial solutions (tuples of per-bin loads with the
    item sets); repeatedly merges the two largest by combining largest bin
    with smallest bin.
    """
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(costs)
    if n == 0:
        return []
    counter = itertools.count()
    # each heap entry: (-spread, tiebreak, loads tuple desc, bins: tuple of
    # tuples of item indices, aligned with loads)
    heap = []
    for i, c in enumerate(costs):
        loads = tuple([float(c)] + [0.0] * (n_bins - 1))
        bins = tuple([(i,)] + [()] * (n_bins - 1))
        heapq.heappush(heap, (-(loads[0] - loads[-1]), next(counter),
                              loads, bins))
    while len(heap) > 1:
        _, _, l1, b1 = heapq.heappop(heap)
        _, _, l2, b2 = heapq.heappop(heap)
        # combine: largest of 1 with smallest of 2, etc.
        loads = [l1[i] + l2[n_bins - 1 - i] for i in range(n_bins)]
        bins = [b1[i] + b2[n_bins - 1 - i] for i in range(n_bins)]
        order = sorted(range(n_bins), key=lambda i: -loads[i])
        loads_t = tuple(loads[i] for i in order)
        bins_t = tuple(bins[i] for i in order)
        heapq.heappush(heap, (-(loads_t[0] - loads_t[-1]), next(counter),
                              loads_t, bins_t))
    _, _, loads, bins = heap[0]
    assign = [0] * n
    for b, items in enumerate(bins):
        for i in items:
            assign[i] = b
    return assign


def multi_greedy_binpack(cost_vectors: Sequence[Sequence[float]],
                         n_bins: int) -> list[int]:
    """Inter-module balancing: each item carries one cost per module
    (e.g. [encoder, backbone]); greedily place items (largest combined
    first) into the bin minimizing the worst per-module normalized load.
    This is the paper's hybrid balance: both module workloads must be flat
    simultaneously because the modules are colocated on the same GPUs."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(cost_vectors)
    if n == 0:
        return []
    dims = len(cost_vectors[0])
    means = [max(sum(v[d] for v in cost_vectors) / n, 1e-12)
             for d in range(dims)]
    norm = [[v[d] / means[d] for d in range(dims)] for v in cost_vectors]
    order = sorted(range(n), key=lambda i: -max(norm[i]))
    loads = [[0.0] * dims for _ in range(n_bins)]
    assign = [0] * n
    for i in order:
        best, best_val = 0, float("inf")
        for b in range(n_bins):
            val = max(loads[b][d] + norm[i][d] for d in range(dims))
            if val < best_val:
                best, best_val = b, val
        assign[i] = best
        for d in range(dims):
            loads[best][d] += norm[i][d]
    return assign


METHODS: dict[str, Callable] = {
    "greedy_binpack": greedy_binpack,
    "karmarkar_karp": karmarkar_karp,
}


def balance_items(costs: Sequence[float], n_bins: int,
                  method: str = "greedy_binpack") -> list[int]:
    try:
        fn = METHODS[method]
    except KeyError:
        raise ValueError(
            f"unknown balance method {method!r}; have {sorted(METHODS)}")
    return fn(costs, n_bins)


def bin_loads(costs: Sequence[float], assign: Sequence[int],
              n_bins: int) -> list[float]:
    loads = [0.0] * n_bins
    for c, b in zip(costs, assign):
        loads[b] += c
    return loads


def imbalance(loads: Sequence[float]) -> float:
    """max/mean — 1.0 is perfect; the paper's Fig. 3 reports up to 6.9x."""
    m = sum(loads) / max(len(loads), 1)
    return max(loads) / m if m > 0 else 1.0
