"""Balancing methods for the ``balance()`` primitive (paper §4.2).

Both operate on (cost, item) pairs and return bin assignments minimizing
the max-bin cost (the straggler — what sets step time under quadratic
attention).  ``greedy_binpack`` is LPT (4/3-approx); ``karmarkar_karp``
is the multiway differencing method (better for few large bins).

The public functions are the vectorized implementations (numpy argsort +
heap, cons-tree merges); the originals are kept as ``_*_reference`` and
exported through ``REFERENCE_METHODS`` so the equivalence tests in
tests/test_balance.py can check assignments item-for-item on randomized
inputs.  Each vectorized version is bit-equivalent by construction: same
stable orderings, same float operation order, same tie-breaking.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional, Sequence

import numpy as np


# --------------------------------------------------------------- reference
def _greedy_binpack_reference(costs: Sequence[float],
                              n_bins: int) -> list[int]:
    """Original LPT: Python sort + heap (kept for equivalence tests)."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    order = sorted(range(len(costs)), key=lambda i: -costs[i])
    heap = [(0.0, b) for b in range(n_bins)]
    heapq.heapify(heap)
    assign = [0] * len(costs)
    for i in order:
        load, b = heapq.heappop(heap)
        assign[i] = b
        heapq.heappush(heap, (load + costs[i], b))
    return assign


def _karmarkar_karp_reference(costs: Sequence[float],
                              n_bins: int) -> list[int]:
    """Original multiway differencing with eager per-merge tuple
    concatenation — O(n) extra work per merge (kept for tests)."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(costs)
    if n == 0:
        return []
    counter = itertools.count()
    heap = []
    for i, c in enumerate(costs):
        loads = tuple([float(c)] + [0.0] * (n_bins - 1))
        bins = tuple([(i,)] + [()] * (n_bins - 1))
        heapq.heappush(heap, (-(loads[0] - loads[-1]), next(counter),
                              loads, bins))
    while len(heap) > 1:
        _, _, l1, b1 = heapq.heappop(heap)
        _, _, l2, b2 = heapq.heappop(heap)
        loads = [l1[i] + l2[n_bins - 1 - i] for i in range(n_bins)]
        bins = [b1[i] + b2[n_bins - 1 - i] for i in range(n_bins)]
        order = sorted(range(n_bins), key=lambda i: -loads[i])
        loads_t = tuple(loads[i] for i in order)
        bins_t = tuple(bins[i] for i in order)
        heapq.heappush(heap, (-(loads_t[0] - loads_t[-1]), next(counter),
                              loads_t, bins_t))
    _, _, loads, bins = heap[0]
    assign = [0] * n
    for b, items in enumerate(bins):
        for i in items:
            assign[i] = b
    return assign


def _multi_greedy_binpack_reference(
        cost_vectors: Sequence[Sequence[float]], n_bins: int) -> list[int]:
    """Original hybrid balance with the O(n·k·d) per-item bin rescan
    (kept for tests)."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(cost_vectors)
    if n == 0:
        return []
    dims = len(cost_vectors[0])
    means = [max(sum(v[d] for v in cost_vectors) / n, 1e-12)
             for d in range(dims)]
    norm = [[v[d] / means[d] for d in range(dims)] for v in cost_vectors]
    order = sorted(range(n), key=lambda i: -max(norm[i]))
    loads = [[0.0] * dims for _ in range(n_bins)]
    assign = [0] * n
    for i in order:
        best, best_val = 0, float("inf")
        for b in range(n_bins):
            val = max(loads[b][d] + norm[i][d] for d in range(dims))
            if val < best_val:
                best, best_val = b, val
        assign[i] = best
        for d in range(dims):
            loads[best][d] += norm[i][d]
    return assign


# -------------------------------------------------------------- vectorized
def greedy_binpack(costs: Sequence[float], n_bins: int) -> list[int]:
    """Longest-processing-time-first.  Returns bin index per item.

    Vectorized ordering: one stable numpy argsort replaces the Python
    keyed sort (identical order — stable descending by cost, original
    index breaking ties), then the same lightest-bin heap placement."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(costs)
    if n == 0:
        return []
    arr = np.asarray(costs, dtype=float)
    order = np.argsort(-arr, kind="stable")
    heap = [(0.0, b) for b in range(n_bins)]
    # already sorted ascending by construction — no heapify needed
    assign = [0] * n
    for i in order:
        load, b = heap[0]
        assign[i] = b
        heapq.heapreplace(heap, (load + float(arr[i]), b))
    return assign


def karmarkar_karp(costs: Sequence[float], n_bins: int) -> list[int]:
    """Multiway Karmarkar-Karp differencing.

    Maintains a heap of partial solutions (tuples of per-bin loads with
    the item sets); repeatedly merges the two largest by combining the
    largest bin with the smallest bin.

    The item sets are cons trees (nested pairs) materialized once at the
    end, so each merge is O(k) instead of O(n + k log k) from eager tuple
    concatenation.  Heap keys (spread, insertion counter) are identical
    to the reference, so pop order — and therefore every assignment — is
    identical."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(costs)
    if n == 0:
        return []
    counter = itertools.count()
    # heap entry: (-spread, tiebreak, loads tuple desc, bins tuple of
    # cons trees aligned with loads).  The unique tiebreak means the
    # trees never participate in comparisons.
    heap = [(-float(c), next(counter),
             tuple([float(c)] + [0.0] * (n_bins - 1)),
             tuple([(i, None)] + [None] * (n_bins - 1)))
            for i, c in enumerate(costs)]
    heapq.heapify(heap)
    while len(heap) > 1:
        _, _, l1, b1 = heapq.heappop(heap)
        _, _, l2, b2 = heapq.heappop(heap)
        loads = [l1[i] + l2[n_bins - 1 - i] for i in range(n_bins)]
        bins = []
        for i in range(n_bins):
            a, b = b1[i], b2[n_bins - 1 - i]
            # cons: merge two trees in O(1) instead of tuple + tuple
            bins.append(a if b is None else b if a is None else (a, b))
        order = sorted(range(n_bins), key=lambda i: -loads[i])
        loads_t = tuple(loads[i] for i in order)
        bins_t = tuple(bins[i] for i in order)
        heapq.heappush(heap, (-(loads_t[0] - loads_t[-1]), next(counter),
                              loads_t, bins_t))
    _, _, loads, bins = heap[0]
    assign = [0] * n
    for b, tree in enumerate(bins):
        stack = [tree]
        while stack:
            node = stack.pop()
            if node is None:
                continue
            if isinstance(node[0], int):      # leaf: (item, None)
                assign[node[0]] = b
            else:
                stack.append(node[0])
                stack.append(node[1])
    return assign


def multi_greedy_binpack(cost_vectors: Sequence[Sequence[float]],
                         n_bins: int) -> list[int]:
    """Inter-module balancing: each item carries one cost per module
    (e.g. [encoder, backbone]); greedily place items (largest combined
    first) into the bin minimizing the worst per-module normalized load.
    This is the paper's hybrid balance: both module workloads must be
    flat simultaneously because the modules are colocated on the same
    GPUs.

    The inner placement scan is one numpy reduction over (bins, dims)
    instead of a Python double loop; per-dim means stay Python sums so
    normalization is bit-identical to the reference (np.sum pairwise
    accumulation would diverge in the last ulp)."""
    if n_bins <= 0:
        raise ValueError("n_bins must be positive")
    n = len(cost_vectors)
    if n == 0:
        return []
    dims = len(cost_vectors[0])
    means = np.array([max(sum(v[d] for v in cost_vectors) / n, 1e-12)
                      for d in range(dims)])
    norm = np.asarray(cost_vectors, dtype=float) / means
    order = np.argsort(-norm.max(axis=1), kind="stable")
    loads = np.zeros((n_bins, dims))
    assign = [0] * n
    for i in order:
        # argmin returns the FIRST minimal bin — same tie-break as the
        # reference's strict-< scan
        best = int(np.argmin((loads + norm[i]).max(axis=1)))
        assign[i] = best
        loads[best] += norm[i]
    return assign


METHODS: dict[str, Callable] = {
    "greedy_binpack": greedy_binpack,
    "karmarkar_karp": karmarkar_karp,
}

# original implementations, keyed like METHODS (equivalence tests)
REFERENCE_METHODS: dict[str, Callable] = {
    "greedy_binpack": _greedy_binpack_reference,
    "karmarkar_karp": _karmarkar_karp_reference,
    "multi_greedy_binpack": _multi_greedy_binpack_reference,
}


def balance_items(costs: Sequence[float], n_bins: int,
                  method: str = "greedy_binpack") -> list[int]:
    try:
        fn = METHODS[method]
    except KeyError:
        raise ValueError(
            f"unknown balance method {method!r}; have {sorted(METHODS)}")
    return fn(costs, n_bins)


def bin_loads(costs: Sequence[float], assign: Sequence[int],
              n_bins: int) -> list[float]:
    loads = [0.0] * n_bins
    for c, b in zip(costs, assign):
        loads[b] += c
    return loads


def imbalance(loads: Sequence[float]) -> float:
    """max/mean — 1.0 is perfect; the paper's Fig. 3 reports up to 6.9x."""
    m = sum(loads) / max(len(loads), 1)
    return max(loads) / m if m > 0 else 1.0
