"""Hardening primitives for the data plane (§6 + docs/FAULT_TOLERANCE.md).

Three building blocks the fault paths share, kept dependency-free so both
``repro.core`` and ``repro.chaos`` can layer on them:

  * RetryPolicy — exponential backoff with DETERMINISTIC jitter (seeded,
    so chaos runs reproduce byte-identical timing decisions), a max
    attempt budget, and retryable-exception classification.
  * CircuitBreaker — per-source storage protection: opens after N
    consecutive read failures, half-open probe after a cooldown, closes
    on probe success.  While open the loader serves from its buffer and
    the Planner re-mixes across healthy sources.
  * DeadLetterQueue — bounded quarantine for corrupted samples with
    source attribution; the loader keeps running instead of dying on a
    bad record.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import math
import random
import threading
import time
from collections import Counter, deque
from typing import Callable, Optional


class TransientIOError(IOError):
    """A storage hiccup worth retrying (network blip, throttling, ...)."""


class CorruptSampleError(ValueError):
    """A record that failed integrity validation; quarantine, don't die."""


# concurrent.futures.TimeoutError only aliases the builtin from 3.11 on
RETRYABLE_DEFAULT: tuple = (TimeoutError, concurrent.futures.TimeoutError,
                            TransientIOError)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff + deterministic jitter + exception classes.

    ``run(fn)`` retries ``fn`` on retryable exceptions up to
    ``max_attempts`` total attempts.  Jitter is derived from
    ``(seed, attempt)`` so two runs with the same policy make identical
    timing decisions — a requirement for the reproducible chaos harness.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.02
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.25          # fraction of the delay that is randomized
    retryable: tuple = RETRYABLE_DEFAULT
    seed: int = 0

    def is_retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, tuple(self.retryable))

    def delay(self, attempt: int) -> float:
        raw = min(self.base_delay_s * self.multiplier ** attempt,
                  self.max_delay_s)
        if self.jitter <= 0:
            return raw
        rng = random.Random(f"{self.seed}:{attempt}")
        return raw * (1.0 - self.jitter * rng.random())

    def run(self, fn: Callable, *args,
            on_retry: Optional[Callable[[int, BaseException], None]] = None,
            **kwargs):
        attempts = max(int(self.max_attempts), 1)
        for attempt in range(attempts):
            try:
                return fn(*args, **kwargs)
            except Exception as e:
                if attempt == attempts - 1 or not self.is_retryable(e):
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                time.sleep(self.delay(attempt))
        raise RuntimeError("unreachable")  # pragma: no cover


class CircuitBreaker:
    """Consecutive-failure breaker with half-open probing (thread-safe).

    States: ``closed`` (normal) -> ``open`` after ``failure_threshold``
    consecutive failures -> ``half_open`` after ``cooldown_s`` (exactly one
    probe allowed) -> ``closed`` on probe success / back to ``open`` on
    probe failure.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    #: numeric encoding for the ``breaker_state`` telemetry gauge
    STATE_VALUES = {"closed": 0.0, "half_open": 0.5, "open": 1.0}

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 0.25,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = max(int(failure_threshold), 1)
        self.cooldown_s = max(float(cooldown_s), 0.0)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._consecutive = 0
        self._opened_at = 0.0
        self._n_failures = 0
        self._n_opens = 0
        self._n_probes = 0

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May a read proceed now?  Transitions open->half_open (one
        probe) once the cooldown has elapsed."""
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN:
                if self._clock() - self._opened_at >= self.cooldown_s:
                    self._state = self.HALF_OPEN
                    self._n_probes += 1
                    return True
                return False
            return False   # half-open: a probe is already in flight

    def record_success(self):
        with self._lock:
            self._state = self.CLOSED
            self._consecutive = 0

    def record_failure(self):
        with self._lock:
            self._n_failures += 1
            self._consecutive += 1
            if self._state == self.HALF_OPEN \
                    or self._consecutive >= self.failure_threshold:
                if self._state != self.OPEN:
                    self._n_opens += 1
                self._state = self.OPEN
                self._opened_at = self._clock()

    def gauge_value(self) -> float:
        """State as a number a Prometheus gauge can carry."""
        return self.STATE_VALUES[self.state]

    def stats(self) -> dict:
        with self._lock:
            return {"state": self._state,
                    "consecutive_failures": self._consecutive,
                    "total_failures": self._n_failures,
                    "opens": self._n_opens, "probes": self._n_probes}


class DeadLetterQueue:
    """Bounded quarantine for corrupted samples (thread-safe).

    Entries carry source attribution and the rejection reason; when the
    queue overflows, oldest entries are evicted but the per-source counts
    keep the full history.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = max(int(capacity), 1)
        self._lock = threading.Lock()
        self._items: deque = deque(maxlen=self.capacity)
        self._counts: Counter = Counter()
        self._total = 0

    def put(self, sample_id: str, source: str, reason: str,
            record: Optional[dict] = None):
        with self._lock:
            self._items.append({
                "sample_id": sample_id, "source": source, "reason": reason,
                "record": record, "time": time.time()})
            self._counts[source] += 1
            self._total += 1

    def items(self) -> list[dict]:
        with self._lock:
            return [dict(it) for it in self._items]

    def sample_ids(self) -> set[str]:
        with self._lock:
            return {it["sample_id"] for it in self._items}

    def counts_by_source(self) -> dict[str, int]:
        with self._lock:
            return dict(self._counts)

    def stats(self) -> dict:
        """The schema ``resilience_report()``/``telemetry_report()`` embed."""
        with self._lock:
            return {"total": self._total, "held": len(self._items),
                    "by_source": dict(self._counts)}

    @property
    def total(self) -> int:
        with self._lock:
            return self._total

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


def validate_positive_policy(policy: RetryPolicy) -> bool:
    """Sanity used by config lint (CFG309): a policy that can never retry
    or sleeps absurdly is a misconfiguration, not a policy."""
    return (policy.max_attempts >= 1 and policy.base_delay_s >= 0.0
            and policy.max_delay_s >= policy.base_delay_s
            and policy.multiplier >= 1.0 and 0.0 <= policy.jitter <= 1.0
            and math.isfinite(policy.max_delay_s))
