"""Data-mixture schedules for the ``mix()`` primitive (paper §4.2).

A schedule maps a training step to per-source sampling weights.  Supports
the paper's scheduled modes (static ratios, staged/warmup curricula) and
the dynamic mode driven by runtime metrics (loss/entropy-adaptive), at
epoch/step/substep granularity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np


def _normalize(w: dict[str, float]) -> dict[str, float]:
    tot = sum(max(v, 0.0) for v in w.values())
    if tot <= 0:
        n = len(w)
        return {k: 1.0 / n for k in w}
    return {k: max(v, 0.0) / tot for k, v in w.items()}


class MixSchedule:
    def weights(self, step: int) -> dict[str, float]:
        raise NotImplementedError

    def observe(self, step: int, metrics: dict) -> None:
        """Hook for dynamic schedules (loss/entropy feedback)."""

    @property
    def sources(self) -> list[str]:
        return sorted(self.weights(0))


@dataclasses.dataclass
class StaticSchedule(MixSchedule):
    ratios: dict[str, float]

    def weights(self, step: int) -> dict[str, float]:
        return _normalize(self.ratios)


@dataclasses.dataclass
class StagedSchedule(MixSchedule):
    """[(until_step, ratios), ...] — warmup / staged training (Gemini-style)."""
    stages: list[tuple[int, dict[str, float]]]

    def weights(self, step: int) -> dict[str, float]:
        for until, ratios in self.stages:
            if step < until:
                return _normalize(ratios)
        return _normalize(self.stages[-1][1])


@dataclasses.dataclass
class CurriculumSchedule(MixSchedule):
    """Easy-to-hard: linearly ramps hard-source weight over ramp_steps."""
    easy: dict[str, float]
    hard: dict[str, float]
    ramp_steps: int

    def weights(self, step: int) -> dict[str, float]:
        t = min(max(step / max(self.ramp_steps, 1), 0.0), 1.0)
        keys = set(self.easy) | set(self.hard)
        return _normalize({
            k: (1 - t) * self.easy.get(k, 0.0) + t * self.hard.get(k, 0.0)
            for k in keys})


class AdaptiveSchedule(MixSchedule):
    """Loss-driven reweighting: sources with higher recent loss get more
    weight (softmax over EMA losses / temperature)."""

    def __init__(self, base: dict[str, float], temperature: float = 1.0,
                 ema: float = 0.9):
        self.base = _normalize(base)
        self.temperature = temperature
        self.ema = ema
        self._loss: dict[str, float] = {}

    def observe(self, step: int, metrics: dict) -> None:
        for src, loss in metrics.get("per_source_loss", {}).items():
            prev = self._loss.get(src, loss)
            self._loss[src] = self.ema * prev + (1 - self.ema) * loss

    def weights(self, step: int) -> dict[str, float]:
        if not self._loss:
            return dict(self.base)
        mx = max(self._loss.values())
        boost = {k: math.exp((self._loss.get(k, mx) - mx)
                             / max(self.temperature, 1e-6))
                 for k in self.base}
        return _normalize({k: self.base[k] * boost.get(k, 1.0)
                           for k in self.base})


def sample_counts(weights: dict[str, float], total: int,
                  rng: np.random.Generator) -> dict[str, int]:
    """Integer sample counts per source for one global batch, stochastic
    rounding so long-run ratios match weights exactly."""
    w = _normalize(weights)
    names = sorted(w)
    probs = np.array([w[n] for n in names])
    draws = rng.multinomial(total, probs)
    return {n: int(c) for n, c in zip(names, draws)}
