"""Planner actor: centralized, declarative data plane (§3, §4).

Per step: collect buffer metadata from Source Loaders -> run the user
strategy over a fresh Orchestration -> emit the LoadingPlan -> direct
loaders to prepare+deposit samples into the Data Constructors.  Also the
control-plane brain: mixture schedule, plan history (replay window for
differential checkpointing), and mixture-driven scaling triggers (§5.2).
"""
from __future__ import annotations

import collections
import pickle
import time
from typing import Callable, Optional

import numpy as np

from repro.core.actors import Actor, ActorHandle, FanOut
from repro.core.mixing import MixSchedule
from repro.core.placetree import ClientPlaceTree
from repro.core.primitives import LoadingPlan, Orchestration
from repro.core.resilience import RetryPolicy
from repro.telemetry import Telemetry, ensure_telemetry


class _HealthyRemix(MixSchedule):
    """Schedule view that zeroes degraded sources, re-mixing their weight
    across healthy ones (circuit-breaker fallback, §6 hardening)."""

    def __init__(self, base: MixSchedule, degraded: set):
        self.base = base
        self.degraded = set(degraded)

    def weights(self, step: int) -> dict[str, float]:
        w = self.base.weights(step)
        healthy = {k: v for k, v in w.items() if k not in self.degraded}
        return healthy if healthy else w

    def observe(self, step: int, metrics: dict) -> None:
        self.base.observe(step, metrics)


class Planner(Actor):
    def __init__(self, tree: ClientPlaceTree, schedule: MixSchedule,
                 strategy: Callable, strategy_params: dict,
                 loaders: dict[str, ActorHandle],
                 constructors: dict[int, ActorHandle],
                 samples_per_step: int, seed: int = 0,
                 scale_threshold: float = 1.5,
                 scale_patience: int = 3,
                 ledger=None,
                 call_retry: Optional[RetryPolicy] = None,
                 telemetry: Optional[Telemetry] = None,
                 plan_ahead: int = 0,
                 fanout: bool = True):
        self.tree = tree
        self.schedule = schedule
        self.strategy = strategy
        self.strategy_params = dict(strategy_params)
        self.loaders = dict(loaders)          # name -> handle
        self.constructors = dict(constructors)
        self.samples_per_step = samples_per_step
        self.seed = seed
        self._planned_through = -1
        self._history: collections.OrderedDict = collections.OrderedDict()
        self._diag_log: list[dict] = []
        # mixture-driven scaling state (§5.2)
        self._weight_ema: dict[str, float] = {}
        self._over_count: collections.Counter = collections.Counter()
        self._under_count: collections.Counter = collections.Counter()
        self.scale_threshold = scale_threshold
        self.scale_patience = scale_patience
        self._scale_events: list[dict] = []
        self._scale_cb: Optional[Callable] = None
        self.ledger = ledger
        self.telemetry = ensure_telemetry(telemetry)
        self.call_retry = call_retry or RetryPolicy(
            max_attempts=2, base_delay_s=0.01, max_delay_s=0.1, seed=seed)
        self._degraded_log: list[dict] = []
        # pipelined planning (docs/PERFORMANCE.md): the Overlord casts
        # advance_to(step + plan_ahead) after every fetch, so steps ahead
        # of the consumer are planned on this mailbox thread while the
        # trainer consumes; fanout=False restores the serial-RPC baseline
        self.plan_ahead = max(int(plan_ahead), 0)
        self.fanout = fanout
        self._last_requested = -1

    # -- wiring ------------------------------------------------------------
    def set_loaders(self, loaders: dict[str, ActorHandle]):
        self.loaders = dict(loaders)

    def set_scale_callback(self, cb: Callable):
        """cb(source, direction) -> None; installed by the AutoScaler."""
        self._scale_cb = cb

    # -- planning ------------------------------------------------------------
    def ensure_planned(self, step: int) -> int:
        self._last_requested = max(self._last_requested, step)
        while self._planned_through < step:
            self._plan_one(self._planned_through + 1)
        return self._planned_through

    def advance_to(self, target: int) -> int:
        """Plan-ahead prefetch: plan steps up to ``target`` in the
        background (the Overlord casts this — no caller blocks on it).
        Steps planned here are already deposited in the constructors by
        the time a client asks, so ``get_batch`` only touches the
        planner on a cold start or after a replan."""
        if self._planned_through >= target:
            return self._planned_through
        with self.telemetry.span("planner.pipeline", target=target,
                                 behind=target - self._planned_through):
            while self._planned_through < target:
                self._plan_one(self._planned_through + 1)
        return self._planned_through

    def replan(self, step: int) -> bool:
        """Re-execute a step's plan after recovery: a planner that died
        mid-plan may have 'planned' a step no constructor holds.  Allowed
        once per step per incarnation (the data differs from the lost plan
        — it is fresh buffered data, which is fine: samples are exchangeable
        within the mixture)."""
        if not hasattr(self, "_replanned"):
            self._replanned: set[int] = set()
        if step in self._replanned or step > self._planned_through:
            return False
        self._replanned.add(step)
        self._plan_one(step)
        return True

    def _collect_buffers(self) -> tuple[list[dict], dict[str, str], set]:
        """Merge loader buffers; map sample_id -> owning loader name; and
        collect the set of DEGRADED sources (open circuit breaker) the
        mixture should route around.  One ``snapshot`` RPC per loader
        (metadata + health merged), issued as a single overlapped wave:
        the collect stage pays one max-latency, not a sum of round-trips.
        """
        alive = {n: h for n, h in self.loaders.items() if h.alive}
        if self.fanout:
            fo = FanOut(telemetry=self.telemetry)
            for name, h in alive.items():
                fo.submit(name, h, "snapshot", timeout=10)
            snaps = fo.gather()
        else:
            snaps = {}
            for name, h in alive.items():   # perf: serial ok — baseline
                try:
                    snaps[name] = h.call("snapshot", timeout=10)
                except Exception:
                    continue
        meta, owner, degraded = [], {}, set()
        for name in alive:                 # loader order, not completion
            snap = snaps.get(name)
            if snap is None:
                continue
            health = snap["health"]
            if health.get("breaker") == "open":
                degraded.add(health["source"])
            for m in snap["entries"]:
                meta.append(m)
                owner[m["sample_id"]] = name
        return meta, owner, degraded

    def _plan_one(self, step: int):
        tel = self.telemetry
        t0 = time.perf_counter()
        with tel.span("planner.plan_step", step=step):
            with tel.span("planner.collect", step=step) as sp:
                buffer_meta, owner, degraded = self._collect_buffers()
                sp.set_attr("buffered", len(buffer_meta))
            schedule = self.schedule
            if degraded:
                # fallback re-mix: weight of broken sources flows to
                # healthy ones instead of starving the step
                # (docs/FAULT_TOLERANCE.md)
                schedule = _HealthyRemix(self.schedule, degraded)
                self._degraded_log.append(
                    {"step": step, "degraded": sorted(degraded)})
                tel.inc("planner_degraded_steps_total")
            with tel.span("planner.strategy", step=step,
                          strategy=getattr(self.strategy, "__name__",
                                           "strategy")):
                # selection + costing + bucketing/binning all run inside
                # the strategy over a fresh Orchestration
                ctx = Orchestration(buffer_meta, self.tree, step, self.seed)
                plan: LoadingPlan = self.strategy(
                    ctx, schedule=schedule, total=self.samples_per_step,
                    **self.strategy_params)
            plan_dispatched = self._dispatch(step, plan, owner)
        tel.observe("planner_plan_seconds", time.perf_counter() - t0)
        self._record_plan_metrics(plan)
        return plan_dispatched

    def _dispatch(self, step: int, plan: LoadingPlan, owner: dict):
        tel = self.telemetry
        # direct loaders: prepare planned samples (transform on the loader),
        # THEN announce realized counts + deposit, so a loader failing
        # mid-plan can never wedge a constructor on missing counts.
        by_loader: dict[str, list] = collections.defaultdict(list)
        for e in plan.entries:
            ln = owner.get(e.sample_id)
            if ln is not None:
                by_loader[ln].append(e)
        with tel.span("planner.dispatch", step=step):
            # stage 1 — prepare: one overlapped wave across loaders, so
            # the transform cost is the max over loaders, not the sum
            prepared = self._prepare_wave(by_loader)
            deposits = collections.defaultdict(list)  # bkt -> [(src,s,bin)]
            for lname, entries in by_loader.items():
                samples = prepared.get(lname)
                if samples is None:
                    continue  # supervision promotes a shadow; degrade
                by_id = {s.sample_id: s for s in samples}
                for e in entries:
                    if e.sample_id in by_id:
                        deposits[e.bucket].append(
                            (e.source, by_id[e.sample_id], e.bin))
            # stage 2 — batched ingest: expect+deposit collapsed into one
            # RPC per constructor, fanned out as a second wave
            accepted = self._ingest_wave(step, plan, deposits)
            for bucket, items in deposits.items():
                if accepted.get(bucket) is not True:
                    # unreachable, or the step is already assembled there
                    # (we are a replan after recovery); re-depositing
                    # would shadow samples a client may have consumed —
                    # first plan wins
                    continue
                per_src = collections.Counter(src for src, _, _ in items)
                for src, n in per_src.items():
                    tel.inc("planner_samples_planned_total", n,
                            source=src)
                if self.ledger is not None:
                    for src, s, b in items:
                        self.ledger.record_planned(step, s.sample_id, src,
                                                   bucket)

        self._history[step] = {
            "per_loader_ids": {ln: [e.sample_id for e in es]
                               for ln, es in by_loader.items()},
            "weights": plan.diagnostics.get("mix_weights", {}),
        }
        while len(self._history) > 64:
            self._history.popitem(last=False)
        self._diag_log.append(
            {"step": step, **{k: v for k, v in plan.diagnostics.items()
                              if k.startswith("balance")}})
        self._planned_through = step
        self._maybe_scale(plan)
        return plan

    def _prepare_wave(self, by_loader: dict) -> dict:
        """prepare() on every owning loader; fan-out unless in serial
        baseline mode.  Returns loader name -> samples (absent on
        failure — supervision handles the dead loader)."""
        targets = {ln: (self.loaders.get(ln),
                        [e.sample_id for e in entries])
                   for ln, entries in by_loader.items()}
        if self.fanout:
            fo = FanOut(telemetry=self.telemetry)
            for ln, (h, ids) in targets.items():
                if h is not None and h.alive:
                    fo.submit(ln, h, "prepare", ids, timeout=60)
            return fo.gather()
        prepared = {}
        for ln, (h, ids) in targets.items():   # perf: serial ok — baseline
            if h is None or not h.alive:
                continue
            try:
                prepared[ln] = h.call("prepare", ids, timeout=60)
            except Exception:
                continue
        return prepared

    def _ingest_wave(self, step: int, plan: LoadingPlan,
                     deposits: dict) -> dict:
        """Batched expect+deposit on EVERY constructor (a bucket with no
        items must still assemble the empty step or its clients wedge).
        Returns bucket -> True (accepted) / False (first-plan-wins
        replan skip); unreachable constructors are absent."""
        payloads = {}
        for bucket in self.constructors:
            per_src: dict = collections.defaultdict(lambda: ([], []))
            for src, s, b in deposits.get(bucket, []):
                per_src[src][0].append(s)
                per_src[src][1].append(b)
            payloads[bucket] = dict(per_src)
        if self.fanout:
            fo = FanOut(telemetry=self.telemetry)
            for bucket, h in self.constructors.items():
                fo.submit(bucket, h, "ingest", step, payloads[bucket],
                          plan.bins, timeout=30, retry=self.call_retry)
            return fo.gather()
        accepted = {}
        for bucket, h in self.constructors.items():  # perf: serial ok
            try:
                accepted[bucket] = h.call(
                    "ingest", step, payloads[bucket], plan.bins,
                    timeout=30, retry=self.call_retry)
            except Exception:
                continue   # constructor unreachable: skip its share
        return accepted

    def _record_plan_metrics(self, plan: LoadingPlan):
        """Balance/throughput gauges derived from the emitted plan."""
        tel = self.telemetry
        if not tel.enabled:
            return
        tel.inc("planner_steps_planned_total")
        tel.set_gauge("planner_planned_through",
                      float(self._planned_through))
        # how far ahead of the consumer the pipeline is running; 0 means
        # planning is on the critical path (cold start / fell behind)
        tel.set_gauge("planner_prefetch_depth",
                      float(self._planned_through - self._last_requested))
        bal = plan.diagnostics.get("balance:main") or {}
        loads = bal.get("bucket_loads") or []
        for bucket, load in enumerate(loads):
            tel.set_gauge("plan_bucket_load", float(load),
                          bucket=bucket)
        if "imbalance" in bal:
            tel.set_gauge("plan_imbalance", float(bal["imbalance"]))

    # -- dynamic mixture scaling (§5.2) ---------------------------------------
    def _maybe_scale(self, plan: LoadingPlan):
        weights = plan.diagnostics.get("mix_weights", {})
        if not weights:
            return
        base = 1.0 / max(len(weights), 1)
        for src, w in weights.items():
            ema = self._weight_ema.get(src, base)
            ema = 0.8 * ema + 0.2 * w
            self._weight_ema[src] = ema
            if ema > self.scale_threshold * base:
                self._over_count[src] += 1
                self._under_count[src] = 0
            elif ema < base / self.scale_threshold:
                self._under_count[src] += 1
                self._over_count[src] = 0
            else:
                self._over_count[src] = 0
                self._under_count[src] = 0
            if self._over_count[src] >= self.scale_patience:
                self._over_count[src] = -self.scale_patience  # cooldown
                self._scale_events.append(
                    {"step": plan.step, "source": src, "dir": "up",
                     "ema": ema})
                if self._scale_cb:
                    self._scale_cb(src, "up")
            if self._under_count[src] >= self.scale_patience:
                self._under_count[src] = -self.scale_patience
                self._scale_events.append(
                    {"step": plan.step, "source": src, "dir": "down",
                     "ema": ema})
                if self._scale_cb:
                    self._scale_cb(src, "down")

    # -- metrics feedback -------------------------------------------------------
    def observe(self, step: int, metrics: dict):
        self.schedule.observe(step, metrics)

    # -- introspection ----------------------------------------------------------
    def diagnostics(self) -> list[dict]:
        return list(self._diag_log)

    def scale_events(self) -> list[dict]:
        return list(self._scale_events)

    def degraded_log(self) -> list[dict]:
        """Steps where the mixture routed around broken sources."""
        return list(self._degraded_log)

    def planned_through(self) -> int:
        return self._planned_through

    def history_window(self) -> dict:
        return {s: h["per_loader_ids"] for s, h in self._history.items()}

    def memory_bytes(self) -> int:
        return len(pickle.dumps(self._history)) \
            + len(pickle.dumps(self._diag_log[-32:]))

    # -- checkpointing -------------------------------------------------------
    def checkpoint_state(self) -> dict:
        return {
            "planned_through": self._planned_through,
            "schedule": pickle.dumps(self.schedule),
            "weight_ema": dict(self._weight_ema),
            "history": pickle.dumps(self._history),
        }

    def restore_state(self, state: dict):
        self._planned_through = state["planned_through"]
        self.schedule = pickle.loads(state["schedule"])
        self._weight_ema = dict(state["weight_ema"])
        self._history = pickle.loads(state["history"])
        # invalidate prefetched plans past the restored step: the dead
        # incarnation may have planned ahead of this checkpoint, but this
        # incarnation must not trust (or replay) plans it cannot prove —
        # ensure_planned replans forward and the constructors' first-plan-
        # wins ingest keeps already-assembled steps authoritative
        for s in [s for s in self._history if s > self._planned_through]:
            del self._history[s]
        self._replanned = set()
        self._last_requested = min(self._last_requested,
                                   self._planned_through)

    def capture_cut(self, include_actors: bool = True) -> dict:
        """One crash-consistent cut of the data plane, taken BETWEEN plan
        steps on this mailbox thread: prepare/ingest waves are gathered
        synchronously inside ``_plan_one``, so when this method runs every
        loader has prepared — and every constructor has ingested — exactly
        the steps in this planner's history, nothing more.  Blobs cut here
        can therefore be replayed forward on resume without divergence
        (the bug a checkpoint taken from the Overlord thread has: the
        plan-ahead pipeline races it).  ``include_actors=False`` captures
        the cheap planner+ledger slice only (differential frequency)."""
        cut = {"frontier": self._planned_through,
               "planner": self.checkpoint_state(),
               "actors": {},
               "ledger": self.ledger.snapshot()
               if self.ledger is not None else None}
        if not include_actors:
            return cut
        handles = {n: h for n, h in self.loaders.items() if h.alive}
        handles.update({f"constructor:{b}": h
                        for b, h in self.constructors.items() if h.alive})
        if self.fanout:
            fo = FanOut(telemetry=self.telemetry)
            for name, h in handles.items():
                fo.submit(name, h, "checkpoint_state", timeout=30)
            cut["actors"] = fo.gather()
        else:
            for name, h in handles.items():  # perf: serial ok — baseline
                try:
                    cut["actors"][name] = h.call("checkpoint_state",
                                                 timeout=30)
                except Exception:
                    continue
        return cut

    def rollback_to(self, step: int):
        """Discard plan state beyond ``step`` (job-level resume).  The
        checkpointed planner may have planned AHEAD of the manifest step
        (plan-ahead prefetch); those steps' constructor deposits died with
        the process, and replaying loaders through them would consume
        buffer samples the deterministic replan needs — so resume rolls
        the frontier back to the manifest step first and replans forward
        from the restored buffers."""
        if step >= self._planned_through:
            return
        for s in [s for s in self._history if s > step]:
            del self._history[s]
        self._planned_through = step
        self._last_requested = min(self._last_requested, step)
        self._replanned = set()
