# OVERLORD data plane — the paper's primary contribution.
from repro.core.actors import Actor, ActorHandle, ActorRuntime  # noqa: F401
from repro.core.balance import (  # noqa: F401
    balance_items, bin_loads, greedy_binpack, imbalance, karmarkar_karp,
    multi_greedy_binpack,
)
from repro.core.dgraph import DGraph  # noqa: F401
from repro.core.mixing import (  # noqa: F401
    AdaptiveSchedule, CurriculumSchedule, MixSchedule, StagedSchedule,
    StaticSchedule,
)
from repro.core.orchestrator import Overlord, OverlordConfig  # noqa: F401
from repro.core.placetree import ClientPlaceTree  # noqa: F401
from repro.core.primitives import LoadingPlan, Orchestration  # noqa: F401
from repro.core.resilience import (  # noqa: F401
    CircuitBreaker, CorruptSampleError, DeadLetterQueue, RetryPolicy,
    TransientIOError,
)
from repro.core.strategies import STRATEGIES  # noqa: F401
