"""SourceLoader actor: per-source ingest + sample transformations (§3).

One actor per (source, data-parallel shard).  Holds exactly ONE set of
file access states (the point of source disaggregation: memory scales with
sources, not sources x ranks x workers).  ``workers`` models worker-
parallel transform slots: transforms of a refill batch are amortized
across workers (P/n), which the AutoScaler provisions (§5).

State = (file cursor, rng counter, buffer contents) — checkpointable, and
replayable from plan history (fault.py's differential checkpointing).
"""
from __future__ import annotations

import sys
import time
from typing import Optional

import numpy as np

from repro.core.actors import Actor
from repro.data.storage import SourceReader
from repro.data.transforms import Sample, record_metadata, transform_record


class SourceLoader(Actor):
    def __init__(self, source: str, path: str,
                 shard: tuple[int, int] = (0, 1), workers: int = 1,
                 buffer_target: int = 256, vocab_size: int = 50_000,
                 work_scale: float = 0.0, seed: int = 0):
        self.source = source
        self.path = path
        self.shard = shard
        self.workers = max(int(workers), 1)
        self.buffer_target = buffer_target
        self.vocab_size = vocab_size
        self.work_scale = work_scale
        self.seed = seed
        self._reader: Optional[SourceReader] = None
        self._buffer: list[dict] = []      # raw records awaiting dispatch
        self._virtual_time = 0.0           # accumulated transform cost units
        self._samples_loaded = 0
        self._fail_next = False

    # -- lifecycle --------------------------------------------------------
    def on_start(self):
        self._reader = SourceReader(self.path, self.shard)
        self.refill()

    def on_stop(self):
        if self._reader is not None:
            self._reader.close()

    # -- buffer management --------------------------------------------------
    def refill(self, target: Optional[int] = None):
        """Read from storage until the buffer reaches its target depth."""
        target = target or self.buffer_target
        need = target - len(self._buffer)
        if need > 0:
            self._buffer.extend(self._reader.read(need))
            self._samples_loaded += need
        return len(self._buffer)

    def summary_buffer(self) -> list[dict]:
        """Metadata the Planner plans over (never payloads)."""
        return [record_metadata(r, self.source) for r in self._buffer]

    # -- plan execution -------------------------------------------------------
    def prepare(self, sample_ids: list[str]) -> list[Sample]:
        """Pop the planned records from the buffer, run sample transforms
        (amortized across worker-parallel slots), return Samples."""
        if self._fail_next:
            self._fail_next = False
            raise RuntimeError(f"injected failure in loader {self.name}")
        wanted = set(sample_ids)
        picked, rest = [], []
        for r in self._buffer:
            (picked if r["sample_id"] in wanted else rest).append(r)
        self._buffer = rest
        out = []
        cost = 0.0
        for r in picked:
            s = transform_record(r, self.source, self.vocab_size,
                                 self.work_scale)
            cost += s.virtual_cost
            out.append(s)
        # worker parallelism amortizes transform latency (paper §5.1: P/n)
        self._virtual_time += cost / self.workers
        self.refill()
        return out

    # -- fault injection / introspection ---------------------------------------
    def inject_failure(self):
        self._fail_next = True

    def stats(self) -> dict:
        return {
            "source": self.source,
            "shard": self.shard,
            "workers": self.workers,
            "buffer_depth": len(self._buffer),
            "virtual_time": self._virtual_time,
            "samples_loaded": self._samples_loaded,
            "cursor": self._reader.tell() if self._reader else 0,
            "access_state_bytes":
                self._reader.access_state_bytes if self._reader else 0,
        }

    def memory_bytes(self) -> int:
        access = self._reader.access_state_bytes if self._reader else 0
        buf = sum(len(r.get("payload", b"")) + 200 for r in self._buffer)
        # each worker slot holds an execution context + prefetch slot
        worker_overhead = self.workers * 64 * 1024
        return access + buf + worker_overhead

    # -- checkpointing -----------------------------------------------------------
    def checkpoint_state(self) -> dict:
        # NOTE: includes the buffer payloads — this is exactly why the
        # paper gives loaders a LOWER checkpoint frequency than the planner
        # and covers the gap with plan replay (differential checkpointing).
        return {
            "source": self.source, "shard": self.shard,
            "cursor": self._reader.tell(),
            "buffer": [dict(r) for r in self._buffer],
            "samples_loaded": self._samples_loaded,
            "virtual_time": self._virtual_time,
        }

    def restore_state(self, state: dict):
        self._buffer = [dict(r) for r in state["buffer"]]
        self._reader.seek(state["cursor"])
        self._samples_loaded = state["samples_loaded"]
        self._virtual_time = state["virtual_time"]

    def replay(self, sample_id_lists: list[list[str]]):
        """Replay planned pops since the restored checkpoint (storage reads
        are deterministic given the cursor, so state converges)."""
        for ids in sample_id_lists:
            self.prepare(ids)
