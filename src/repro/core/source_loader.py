"""SourceLoader actor: per-source ingest + sample transformations (§3).

One actor per (source, data-parallel shard).  Holds exactly ONE set of
file access states (the point of source disaggregation: memory scales with
sources, not sources x ranks x workers).  ``workers`` models worker-
parallel transform slots: transforms of a refill batch are amortized
across workers (P/n), which the AutoScaler provisions (§5).

State = (file cursor, rng counter, buffer contents) — checkpointable, and
replayable from plan history (fault.py's differential checkpointing).

Hardened paths (docs/FAULT_TOLERANCE.md): storage reads go through a
RetryPolicy and a per-source CircuitBreaker (open after N consecutive
failures, half-open probe after a cooldown; while open, the loader serves
from its buffer and the Planner re-mixes across healthy sources), and
corrupted records are quarantined into a DeadLetterQueue with source
attribution instead of killing the actor.  ``inject_fault`` is the
deterministic entry point the chaos harness drives.
"""
from __future__ import annotations

import sys
import time
from typing import Any, Optional

import numpy as np

from repro.core.actors import Actor
from repro.core.resilience import (
    CircuitBreaker, CorruptSampleError, DeadLetterQueue, RetryPolicy,
    TransientIOError,
)
from repro.data.storage import SourceReader
from repro.data.transforms import (
    Sample, record_metadata, transform_record, validate_record,
)
from repro.telemetry import Telemetry, ensure_telemetry


class SourceLoader(Actor):
    def __init__(self, source: str, path: str,
                 shard: tuple[int, int] = (0, 1), workers: int = 1,
                 buffer_target: int = 256, vocab_size: int = 50_000,
                 work_scale: float = 0.0, seed: int = 0,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 dlq: Optional[DeadLetterQueue] = None,
                 telemetry: Optional[Telemetry] = None):
        self.source = source
        self.path = path
        self.shard = shard
        self.workers = max(int(workers), 1)
        self.buffer_target = buffer_target
        self.vocab_size = vocab_size
        self.work_scale = work_scale
        self.seed = seed
        self.retry = retry or RetryPolicy(max_attempts=3,
                                          base_delay_s=0.01,
                                          max_delay_s=0.2, seed=seed)
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        # NOT `dlq or ...`: an empty DeadLetterQueue is falsy (len 0) and
        # `or` would silently replace the shared queue with a private one
        self.dlq = dlq if dlq is not None else DeadLetterQueue()
        self.telemetry = ensure_telemetry(telemetry)
        self._reader: Optional[SourceReader] = None
        self._buffer: list[dict] = []      # raw records awaiting dispatch
        self._virtual_time = 0.0           # accumulated transform cost units
        self._samples_loaded = 0
        self._fail_next = False
        self._read_failures = 0
        self._quarantined = 0
        # chaos state (inject_fault): remaining io-error reads, slow-call
        # budget, pending hang seconds
        self._chaos: dict[str, Any] = {"io_error": 0, "slow_calls": 0,
                                       "slow_delay": 0.0, "corrupt_next": 0}

    # -- lifecycle --------------------------------------------------------
    def on_start(self):
        self._reader = SourceReader(self.path, self.shard)
        self.refill()

    def on_stop(self):
        if self._reader is not None:
            self._reader.close()

    # -- buffer management --------------------------------------------------
    def _read(self, need: int) -> list[dict]:
        if self._chaos["io_error"] > 0:
            self._chaos["io_error"] -= 1
            raise TransientIOError(
                f"injected io error on {self.source}")
        return self._reader.read(need)

    def refill(self, target: Optional[int] = None):
        """Read from storage until the buffer reaches its target depth.

        Reads are retried per RetryPolicy; persistent failure trips the
        circuit breaker and the loader keeps serving from its buffer."""
        target = target or self.buffer_target
        need = target - len(self._buffer)
        if need <= 0:
            return len(self._buffer)
        tel = self.telemetry
        with tel.span("loader.refill", source=self.source,
                      need=need) as sp:
            if not self.breaker.allow():
                sp.set_attr("skipped", "breaker_open")
                self._sample_gauges()
                return len(self._buffer)   # open: degrade, don't block
            try:
                records = self.retry.run(
                    self._read, need, on_retry=self._on_read_retry)
            except Exception:
                self._read_failures += 1
                self.breaker.record_failure()
                tel.inc("loader_read_failures_total", 1.0,
                        source=self.source)
                sp.set_attr("failed", True)
                self._sample_gauges()
                return len(self._buffer)
            self.breaker.record_success()
            self._buffer.extend(records)
            self._samples_loaded += len(records)
            tel.inc("loader_records_read_total", len(records),
                    source=self.source)
        self._sample_gauges()
        return len(self._buffer)

    def _on_read_retry(self, attempt: int, exc: BaseException):
        self.telemetry.inc("loader_read_retries_total", 1.0,
                           source=self.source)

    def _sample_gauges(self):
        tel = self.telemetry
        if not tel.enabled:
            return
        tel.set_gauge("loader_buffer_depth", float(len(self._buffer)),
                      source=self.source, actor=self.name)
        tel.set_gauge("breaker_state", self.breaker.gauge_value(),
                      source=self.source)

    def summary_buffer(self) -> list[dict]:
        """Metadata the Planner plans over (never payloads)."""
        return [record_metadata(r, self.source) for r in self._buffer]

    def snapshot(self) -> dict:
        """Buffer metadata + health in ONE round-trip.  The Planner's
        collect stage used to pay two blocking RPCs per loader
        (``summary_buffer`` then ``health``); planning latency is on the
        step critical path, so the pair is collapsed into one mailbox
        message per loader per step."""
        return {"entries": self.summary_buffer(), "health": self.health()}

    # -- plan execution -------------------------------------------------------
    def prepare(self, sample_ids: list[str]) -> list[Sample]:
        """Pop the planned records from the buffer, run sample transforms
        (amortized across worker-parallel slots), return Samples.
        Corrupted records are quarantined into the DLQ, not raised."""
        tel = self.telemetry
        with tel.span("loader.prepare", source=self.source,
                      n=len(sample_ids)):
            self._chaos_latency()
            if self._fail_next:
                self._fail_next = False
                raise RuntimeError(
                    f"injected failure in loader {self.name}")
            wanted = set(sample_ids)
            picked, rest = [], []
            for r in self._buffer:
                (picked if r["sample_id"] in wanted else rest).append(r)
            self._buffer = rest
            out = []
            cost = 0.0
            for r in picked:
                if self._chaos["corrupt_next"] > 0:
                    self._chaos["corrupt_next"] -= 1
                    r = dict(r)
                    r["_corrupt"] = "chaos"
                try:
                    validate_record(r)
                except CorruptSampleError as e:
                    self._quarantined += 1
                    self.dlq.put(str(r.get("sample_id", "?")), self.source,
                                 str(e))
                    tel.inc("loader_quarantined_total", 1.0,
                            source=self.source)
                    continue
                s = transform_record(r, self.source, self.vocab_size,
                                     self.work_scale)
                cost += s.virtual_cost
                out.append(s)
            # worker parallelism amortizes transform latency (§5.1: P/n)
            self._virtual_time += cost / self.workers
            if out:
                tel.inc("loader_samples_prepared_total", len(out),
                        source=self.source)
                tel.observe("loader_prepare_cost", cost,
                            source=self.source)
            self.refill()
            return out

    # -- fault injection / introspection ---------------------------------------
    def inject_failure(self):
        self._fail_next = True

    def inject_fault(self, kind: str, **params) -> dict:
        """Deterministic fault hooks the chaos harness drives via cast():

          * hang     — sleep ``seconds`` on the mailbox thread NOW
          * slow     — delay the next ``calls`` prepare() calls by ``delay``
          * io_error — fail the next ``reads`` storage reads (feeds the
                       retry policy and circuit breaker)
          * corrupt  — poison the next ``samples`` records prepare() pops
                       (caught by validate_record -> DLQ)
          * crash    — raise on the next prepare() (legacy inject_failure)
        """
        if kind == "hang":
            time.sleep(float(params.get("seconds", 0.2)))
        elif kind == "slow":
            self._chaos["slow_calls"] = int(params.get("calls", 3))
            self._chaos["slow_delay"] = float(params.get("delay", 0.02))
        elif kind == "io_error":
            self._chaos["io_error"] += int(params.get("reads", 3))
        elif kind == "corrupt":
            self._chaos["corrupt_next"] += int(params.get("samples", 3))
        elif kind == "crash":
            self._fail_next = True
        else:
            raise ValueError(f"unknown fault kind {kind!r}")
        return {"kind": kind, "params": dict(params), "source": self.source}

    def _chaos_latency(self):
        if self._chaos["slow_calls"] > 0:
            self._chaos["slow_calls"] -= 1
            time.sleep(self._chaos["slow_delay"])

    def health(self) -> dict:
        """Degradation signal the Planner re-mixes on (breaker state)."""
        return {
            "source": self.source,
            "breaker": self.breaker.state,
            "read_failures": self._read_failures,
            "quarantined": self._quarantined,
            "buffer_depth": len(self._buffer),
        }

    def stats(self) -> dict:
        return {
            "source": self.source,
            "shard": self.shard,
            "workers": self.workers,
            "buffer_depth": len(self._buffer),
            "virtual_time": self._virtual_time,
            "samples_loaded": self._samples_loaded,
            "read_failures": self._read_failures,
            "quarantined": self._quarantined,
            "breaker": self.breaker.stats(),
            "cursor": self._reader.tell() if self._reader else 0,
            "access_state_bytes":
                self._reader.access_state_bytes if self._reader else 0,
        }

    def memory_bytes(self) -> int:
        access = self._reader.access_state_bytes if self._reader else 0
        buf = sum(len(r.get("payload", b"")) + 200 for r in self._buffer)
        # each worker slot holds an execution context + prefetch slot
        worker_overhead = self.workers * 64 * 1024
        return access + buf + worker_overhead

    # -- checkpointing -----------------------------------------------------------
    def checkpoint_state(self) -> dict:
        # NOTE: includes the buffer payloads — this is exactly why the
        # paper gives loaders a LOWER checkpoint frequency than the planner
        # and covers the gap with plan replay (differential checkpointing).
        return {
            "source": self.source, "shard": self.shard,
            "cursor": self._reader.tell(),
            "buffer": [dict(r) for r in self._buffer],
            "samples_loaded": self._samples_loaded,
            "virtual_time": self._virtual_time,
        }

    def restore_state(self, state: dict):
        # identity guard: a manifest built for another (source, shard)
        # must not silently seed this loader's cursor (fenced resume maps
        # states by actor name; a mismatch means the mapping is wrong)
        if state.get("source", self.source) != self.source \
                or tuple(state.get("shard", self.shard)) != \
                tuple(self.shard):
            raise ValueError(
                f"checkpoint for {state.get('source')}:{state.get('shard')}"
                f" offered to loader {self.source}:{self.shard}")
        self._buffer = [dict(r) for r in state["buffer"]]
        self._reader.seek(state["cursor"])
        self._samples_loaded = state["samples_loaded"]
        self._virtual_time = state["virtual_time"]

    def replay(self, sample_id_lists: list[list[str]]):
        """Replay planned pops since the restored checkpoint (storage reads
        are deterministic given the cursor, so state converges)."""
        for ids in sample_id_lists:
            self.prepare(ids)
