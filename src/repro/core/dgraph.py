"""DGraph: stateful dataflow graph over sample METADATA (paper §4.1).

Nodes are training samples in a processing state; DAG edges record
transformations / logical dependencies (microbatch grouping, bucket
assignment).  DGraph operates on metadata only — payloads never enter the
planner — which is what makes centralized orchestration cheap.

Two core properties from the paper:
  * unified multisource representation: several modality-specific graphs
    can be derived from the same buffer dict via ``select`` predicates
    (e.g. an image DGraph and a text DGraph over one VLM batch);
  * orchestration transparency: ``lineage()`` reconstructs every decision
    applied to a sample, ``to_dot()`` renders the graph.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

# node states (sample lifecycle)
BUFFERED = "buffered"        # sitting in a Source Loader read buffer
SELECTED = "selected"        # chosen by mix() for this step
COSTED = "costed"            # cost() annotated
BUCKETED = "bucketed"        # assigned to a distribute() bucket
BINNED = "binned"            # assigned to a microbatch bin
DELIVERED = "delivered"      # shipped to a Data Constructor


@dataclasses.dataclass
class DNode:
    nid: int
    meta: dict                       # sample metadata (source, sizes, ...)
    state: str = BUFFERED
    cost: float = 0.0
    bucket: Optional[int] = None     # distribute() bucket (e.g. DP rank)
    bin: Optional[int] = None        # microbatch index within bucket
    parents: list = dataclasses.field(default_factory=list)
    edges: list = dataclasses.field(default_factory=list)  # (label, nid)

    @property
    def sample_id(self) -> str:
        return self.meta["sample_id"]

    @property
    def source(self) -> str:
        return self.meta["source"]


class DGraph:
    def __init__(self, nodes: Sequence[DNode], name: str = "dgraph"):
        self.name = name
        self.nodes = list(nodes)
        self._by_id = {n.nid: n for n in self.nodes}

    # -- construction -----------------------------------------------------
    @classmethod
    def from_buffer(cls, buffer_meta: Sequence[dict], name: str = "dgraph",
                    select: Optional[Callable[[dict], bool]] = None) -> \
            "DGraph":
        """Build from Source Loader buffer metadata.  ``select`` carves a
        modality-specific view out of the shared dict (paper: image DGraph
        'inferred using the same buffer but different metadata')."""
        ids = itertools.count()
        nodes = [DNode(next(ids), dict(m)) for m in buffer_meta
                 if select is None or select(m)]
        return cls(nodes, name)

    def derive(self, name: str, select: Callable[[dict], bool]) -> "DGraph":
        """A sub-view sharing the SAME node objects (mutations visible in
        both graphs — one sample, several orchestration views)."""
        return DGraph([n for n in self.nodes if select(n.meta)], name)

    # -- state transitions --------------------------------------------------
    def mark(self, nodes: Sequence[DNode], state: str, label: str = ""):
        for n in nodes:
            n.edges.append((label or state, n.state))
            n.state = state

    def with_cost(self, costfn: Callable[[dict], float]):
        for n in self.nodes:
            n.cost = float(costfn(n.meta))
            n.edges.append(("cost", n.cost))
            n.state = COSTED
        return self

    def assign_buckets(self, assign: Sequence[int]):
        assert len(assign) == len(self.nodes)
        for n, b in zip(self.nodes, assign):
            n.bucket = int(b)
            n.edges.append(("bucket", int(b)))
            n.state = BUCKETED
        return self

    def assign_bins(self, nodes: Sequence[DNode], assign: Sequence[int]):
        for n, b in zip(nodes, assign):
            n.bin = int(b)
            n.edges.append(("bin", int(b)))
            n.state = BINNED
        return self

    # -- queries --------------------------------------------------------------
    def by_bucket(self) -> dict[int, list[DNode]]:
        out: dict[int, list[DNode]] = {}
        for n in self.nodes:
            if n.bucket is not None:
                out.setdefault(n.bucket, []).append(n)
        return out

    def by_source(self) -> dict[str, list[DNode]]:
        out: dict[str, list[DNode]] = {}
        for n in self.nodes:
            out.setdefault(n.source, []).append(n)
        return out

    def costs(self) -> list[float]:
        return [n.cost for n in self.nodes]

    def lineage(self, sample_id: str) -> list:
        for n in self.nodes:
            if n.sample_id == sample_id:
                return list(n.edges)
        raise KeyError(sample_id)

    # -- transparency -----------------------------------------------------------
    def to_dot(self, max_nodes: int = 40) -> str:
        lines = [f'digraph "{self.name}" {{']
        for n in self.nodes[:max_nodes]:
            lbl = f"{n.sample_id}\\n{n.state}"
            if n.bucket is not None:
                lbl += f"\\nbucket={n.bucket} bin={n.bin}"
            lines.append(f'  n{n.nid} [label="{lbl}"];')
        lines.append("}")
        return "\n".join(lines)

    def __len__(self) -> int:
        return len(self.nodes)
