"""Overlord: the end-to-end public API of the data plane.

Wires together: auto-partitioned Source Loaders (+hot shadows), per-bucket
Data Constructors, the central Planner, trainer clients with prefetch, the
checkpoint store with differential frequencies, and the mixture-driven
AutoScaler.  This is the object launch/train.py and the examples use.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.actors import ActorRuntime
from repro.core.autoscale import (
    LoaderConfig, MixtureScaler, PartitionLimits, SourceProfile,
    auto_partition,
)
from repro.core.client import TrainerClient
from repro.core.constructor import DataConstructor
from repro.core.fault import CheckpointStore, ShadowManager
from repro.core.mixing import MixSchedule, StaticSchedule
from repro.core.placetree import ClientPlaceTree
from repro.core.planner import Planner
from repro.core.resilience import (
    CircuitBreaker, DeadLetterQueue, RetryPolicy,
)
from repro.core.source_loader import SourceLoader
from repro.core.strategies import STRATEGIES
from repro.data.storage import SourceReader
from repro.telemetry import (
    Telemetry, chrome_trace, render_prometheus, write_chrome_trace,
)


@dataclasses.dataclass
class OverlordConfig:
    seq_len: int = 512
    rows_per_microbatch: int = 4      # packed rows per bucket per bin
    n_bins: int = 2                   # microbatches per bucket
    samples_per_step: int = 0         # 0 -> auto from capacity
    strategy: str = "backbone_balance"
    strategy_params: dict = dataclasses.field(default_factory=dict)
    prefetch: int = 2
    # pipelined planning (docs/PERFORMANCE.md; validated by CFG310):
    # plan_ahead=N keeps N steps planned beyond the newest fetch so
    # get_batch never waits on the planner in steady state; 0 restores
    # the fully demand-driven serial path.  fanout_rpc=False falls back
    # to one-RPC-at-a-time planning (the measured baseline in
    # benchmarks/orchestration.run_pipeline).
    plan_ahead: int = 2
    fanout_rpc: bool = True
    buffer_target: int = 256         # loader read-buffer depth (records)
    auto_partition: bool = True
    limits: PartitionLimits = dataclasses.field(
        default_factory=PartitionLimits)
    shadows: bool = True
    checkpoint_dir: Optional[str] = None
    planner_ckpt_every: int = 1
    loader_ckpt_every: int = 8
    restore_delay_s: float = 0.0     # simulated persistent-store latency
    # durable job recovery (docs/FAULT_TOLERANCE.md; validated by CFG311):
    # with checkpoint_dir set, every manifest_every-th step_done commits a
    # crash-consistent epoch manifest (blobs + delivery ledger) that
    # Overlord.resume() restarts from; keep_epochs old epochs are retained
    # for corruption fallback before GC reclaims them
    manifest_every: int = 1
    keep_epochs: int = 3
    vocab_size: int = 50_000
    seed: int = 0
    fill_factor: float = 0.6          # packing headroom
    # resilience (docs/FAULT_TOLERANCE.md; validated by CFG309)
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    breaker_failures: int = 3         # consecutive read failures -> open
    breaker_cooldown_s: float = 0.25  # open -> half-open probe delay
    dlq_capacity: int = 4096          # quarantine depth (oldest evicted)
    ledger: bool = False              # per-sample delivery accounting
    # unified telemetry plane (docs/TELEMETRY.md)
    telemetry: bool = True            # metrics + trace spans
    telemetry_max_spans: int = 65536  # bounded span retention


class Overlord:
    def __init__(self, source_paths: dict[str, str],
                 tree: ClientPlaceTree, schedule: MixSchedule,
                 cfg: OverlordConfig = OverlordConfig(),
                 validate: bool = True):
        self.paths = dict(source_paths)
        self.tree = tree
        self.schedule = schedule
        self.cfg = cfg
        # static analysis before anything is spawned: a bad composition
        # fails here with findings instead of hanging the first step
        # (docs/ANALYSIS.md; disable with validate=False)
        self.analysis = None
        if validate:
            from repro.analysis import AnalysisError, validate_launch
            self.analysis = validate_launch(
                cfg, tree, n_sources=len(self.paths))
            if not self.analysis.ok:
                raise AnalysisError(self.analysis)
        self.telemetry = Telemetry(enabled=cfg.telemetry,
                                   max_spans=cfg.telemetry_max_spans,
                                   seed=cfg.seed)
        self.runtime = ActorRuntime(telemetry=self.telemetry)
        self.store = CheckpointStore(cfg.checkpoint_dir,
                                     cfg.planner_ckpt_every,
                                     cfg.loader_ckpt_every,
                                     cfg.restore_delay_s,
                                     keep_epochs=cfg.keep_epochs)
        self.dlq = DeadLetterQueue(cfg.dlq_capacity)
        self.ledger = None
        if cfg.ledger:
            from repro.chaos.ledger import DeliveryLedger
            self.ledger = DeliveryLedger()
        self.loaders: dict[str, object] = {}
        self.constructors: dict[int, object] = {}
        self.clients: dict[int, TrainerClient] = {}
        self.planner = None
        self._planner_args = None
        self.shadow_mgr: Optional[ShadowManager] = None
        self.scaler: Optional[MixtureScaler] = None
        self._loader_cfgs: dict[str, LoaderConfig] = {}
        self._started = False
        self._lock = threading.Lock()
        self._nudged_to = -1      # highest plan-ahead target cast so far
        self._delivered_ids: set = set()   # unique data-role sample ids
        self.recovery_log: list[dict] = []
        self.resume_report: Optional[dict] = None

    # ----------------------------------------------------------- profiles
    def _profile_sources(self) -> list[SourceProfile]:
        profs = []
        for name, path in self.paths.items():
            with SourceReader(path) as r:
                recs = r.read(8)
                cost = float(np.mean([rc["transform_cost"] for rc in recs]))
                mem = r.access_state_bytes
            profs.append(SourceProfile(name, cost, mem,
                                       1.0 / max(len(self.paths), 1)))
        return profs

    # -------------------------------------------------------------- start
    def start(self, spawn_clients: bool = True):
        """Bring up the data plane.  ``spawn_clients=False`` defers the
        trainer clients (resume uses this: clients start prefetching the
        moment they exist, and a client prefetching step 0 against a
        restored-but-not-yet-replayed plane would corrupt it)."""
        assert not self._started
        cfg = self.cfg
        if cfg.checkpoint_dir:
            # claim the job fence FIRST: from here on, any zombie
            # incarnation of a previous process is locked out of commits
            self.store.acquire_fence()
        if cfg.samples_per_step == 0:
            nb = self.tree.buckets(
                cfg.strategy_params.get("axis", "DP"))
            capacity = nb * cfg.n_bins * cfg.rows_per_microbatch \
                * cfg.seq_len
            # rough mean tokens/sample for sizing
            cfg.samples_per_step = max(
                nb * cfg.n_bins,
                int(capacity * cfg.fill_factor / 96))

        # loaders (phase-1 auto-partitioning)
        if cfg.auto_partition:
            lcfgs = auto_partition(self._profile_sources(), cfg.limits)
        else:
            lcfgs = [LoaderConfig(n, 0, 1, 1) for n in self.paths]
        for lc in lcfgs:
            h = self.runtime.spawn(lc.actor_name, self._make_loader(lc))
            self.loaders[lc.actor_name] = h
            self._loader_cfgs[lc.actor_name] = lc

        # constructors: one per bucket at the distribute axis.  The ready
        # queue must hold every step between the slowest consumer and the
        # plan-ahead frontier, or prefetched-but-unconsumed steps get
        # evicted (and replanned — duplicating delivery)
        axis = cfg.strategy_params.get("axis", "DP")
        queue_depth = max(4, cfg.plan_ahead + cfg.prefetch + 4)
        for b in range(self.tree.buckets(axis)):
            h = self.runtime.spawn(
                f"constructor:{b}",
                DataConstructor(b, self.tree, cfg.seq_len,
                                cfg.rows_per_microbatch, cfg.n_bins,
                                queue_depth=queue_depth,
                                ledger=self.ledger,
                                telemetry=self.telemetry))
            self.constructors[b] = h

        # planner
        strategy = STRATEGIES[cfg.strategy]
        sparams = dict(cfg.strategy_params)
        sparams.setdefault("n_bins", cfg.n_bins)
        self._planner_args = dict(
            tree=self.tree, schedule=self.schedule, strategy=strategy,
            strategy_params=sparams,
            samples_per_step=cfg.samples_per_step, seed=cfg.seed,
            ledger=self.ledger, telemetry=self.telemetry,
            plan_ahead=cfg.plan_ahead, fanout=cfg.fanout_rpc)
        self.planner = self.runtime.spawn(
            "planner", Planner(loaders=dict(self.loaders),
                               constructors=dict(self.constructors),
                               **self._planner_args))

        # shadows + supervision
        if cfg.shadows:
            self.shadow_mgr = ShadowManager(self.runtime, self._make_shadow)
            for name in list(self.loaders):
                self.shadow_mgr.ensure_shadow(name)
        self.runtime.on_failure(self._on_actor_failure)

        # online mixture scaler
        self.scaler = MixtureScaler(
            self.runtime, self.paths,
            register=self._register_loader,
            unregister=self._unregister_loader,
            loader_factory=self._make_loader)
        self.planner.call("set_scale_callback", self.scaler.on_trigger,
                          retry=self.cfg.retry)

        # trainer clients
        if spawn_clients:
            self._spawn_clients(start_step=0)
        self._started = True
        return self

    def _spawn_clients(self, start_step: int) -> None:
        for rank in range(self.tree.world):
            self.clients[rank] = TrainerClient(
                rank, self._fetch_view, prefetch=self.cfg.prefetch,
                start_step=start_step)

    def _make_loader(self, lc: LoaderConfig) -> SourceLoader:
        return SourceLoader(lc.source, self.paths[lc.source],
                            (lc.shard_index, lc.shard_count), lc.workers,
                            buffer_target=self.cfg.buffer_target,
                            vocab_size=self.cfg.vocab_size,
                            seed=self.cfg.seed,
                            retry=self.cfg.retry,
                            breaker=CircuitBreaker(
                                self.cfg.breaker_failures,
                                self.cfg.breaker_cooldown_s),
                            dlq=self.dlq,
                            telemetry=self.telemetry)

    def _make_shadow(self, name: str) -> SourceLoader:
        return self._make_loader(self._loader_cfgs[name])

    # ------------------------------------------------------ loader churn
    def _register_loader(self, name: str, handle):
        with self._lock:
            self.loaders[name] = handle
            parts = name.split(":")
            idx, cnt = parts[2].split("of")
            self._loader_cfgs[name] = LoaderConfig(
                parts[1], int(idx), int(cnt), 2)
        # cast, not call: register/unregister run inside the scale
        # callback, which the planner fires ON its own mailbox thread —
        # a synchronous call back into the planner would self-deadlock
        # (the ACT503 pattern).  Mailbox FIFO still orders the update
        # before any later plan.
        try:
            self.planner.cast("set_loaders", dict(self.loaders))
        except Exception:
            pass   # planner mid-recovery re-syncs the loader map itself
        if self.shadow_mgr:
            self.shadow_mgr.ensure_shadow(name)

    def _unregister_loader(self, name: str):
        with self._lock:
            self.loaders.pop(name, None)
        try:
            self.planner.cast("set_loaders", dict(self.loaders))
        except Exception:
            pass   # planner mid-recovery re-syncs the loader map itself

    # ------------------------------------------------------- supervision
    def _on_actor_failure(self, name: str, handle):
        t0 = time.time()
        if name == "planner":
            self._recover_planner()
        elif name.startswith("loader:") and "::shadow" not in name:
            self._recover_loader(name)
        self.recovery_log.append(
            {"actor": name, "recovery_s": time.time() - t0,
             "time": time.time()})

    def _recover_planner(self):
        ckpt = self.store.load("planner")
        self.planner = self.runtime.spawn(
            "planner", Planner(loaders=dict(self.loaders),
                               constructors=dict(self.constructors),
                               **self._planner_args))
        if ckpt:
            self.planner.call("restore_state", ckpt["state"],
                              retry=self.cfg.retry)
        self.planner.call("set_scale_callback", self.scaler.on_trigger,
                          retry=self.cfg.retry)

    def _replay_since(self, handle, name: str, since_step: int):
        """Replay the plan history window > since_step against a restored
        loader so already-planned samples are consumed, not re-served."""
        try:
            hist = self.planner.call("history_window", timeout=10,
                                     retry=self.cfg.retry)
        except Exception:
            return   # planner down too; its own recovery replans the gap
        replay = [ids.get(name, []) for s, ids in sorted(hist.items())
                  if s > since_step]
        replay = [r for r in replay if r]
        if replay:
            try:
                handle.call("replay", replay, timeout=30,
                            retry=self.cfg.retry)
            except Exception:
                pass   # degraded: at worst the ledger flags duplicates

    def _recover_loader(self, name: str):
        promoted = None
        if self.shadow_mgr:
            promoted = self.shadow_mgr.promote(name)
        if promoted is not None:
            with self._lock:
                self.loaders[name] = promoted
            # the shadow mirrors state as of its last successful sync;
            # plans issued after that already delivered samples the
            # shadow still buffers — replay them forward or they would
            # be delivered twice
            self._replay_since(promoted, name,
                               self.shadow_mgr.synced_step(name))
        else:
            # cold path: restore from checkpoint + replay plan history
            h = self.runtime.spawn(name, self._make_loader(
                self._loader_cfgs[name]))
            ckpt = self.store.load(name)
            if ckpt:
                try:
                    h.call("restore_state", ckpt["state"],
                           retry=self.cfg.retry)
                except Exception:
                    pass   # fresh loader state; replay still converges
                self._replay_since(h, name, ckpt["step"])
            with self._lock:
                self.loaders[name] = h
        try:
            self.planner.call("set_loaders", dict(self.loaders),
                              retry=self.cfg.retry)
        except Exception:
            pass   # planner recovery re-syncs the loader map itself
        if self.shadow_mgr:
            self.shadow_mgr.ensure_shadow(name)

    # ---------------------------------------------------------- data path
    def _bucket_of(self, rank: int, axis: str) -> int:
        view = self.tree.client_view(rank, axis)
        return min(view.dp_index, max(self.constructors)) \
            if self.constructors else 0

    def _nudge_planner(self, step: int) -> None:
        """Keep the plan-ahead window full: a non-blocking cast moves the
        planner's frontier to ``step + plan_ahead`` while the trainer
        consumes ``step``.  Monotonic + deduplicated so each target is
        cast at most once across ranks and prefetch threads."""
        target = step + self.cfg.plan_ahead
        with self._lock:
            if target <= self._nudged_to:
                return
            self._nudged_to = target
        try:
            self.planner.cast("advance_to", target)
        except Exception:
            pass   # planner mid-recovery: the next fetch re-nudges

    def _fetch_view(self, step: int, rank: int) -> Optional[dict]:
        axis = self.cfg.strategy_params.get("axis", "DP")
        bucket = self._bucket_of(rank, axis)
        ch = self.constructors.get(bucket)
        if ch is None:
            return None
        out = None
        if self.cfg.plan_ahead > 0:
            # fast path: a prefetched step is already assembled in the
            # constructor — no planner round-trip on the critical path
            try:
                out = ch.call("get_view", step, rank, axis)
            except Exception:
                out = None
        if out is None:
            # cold start / replan / pipelining off: block on the planner
            try:
                self.planner.call("ensure_planned", step, timeout=120)
            except Exception:
                return None  # planner down: prefetch buffer rides through
            try:
                out = ch.call("get_view", step, rank, axis)
                if out is None:
                    # planner died mid-plan: the step is 'planned' but
                    # lost — replan it once (fresh buffered data; see
                    # Planner.replan)
                    if self.planner.call("replan", step):
                        out = ch.call("get_view", step, rank, axis)
            except Exception:
                return None
        if out is not None and self.cfg.plan_ahead > 0:
            self._nudge_planner(step)
        return out

    def get_batch(self, step: int, rank: int, timeout: float = 60.0) -> dict:
        tel = self.telemetry
        t0 = time.perf_counter()
        with tel.span("overlord.get_batch", step=step, rank=rank):
            view = self.clients[rank].get(step, timeout=timeout)
            if view.get("role") == "data":
                ids = {sid for b in view["bins"] for row in b.doc_ids
                       for sid in row}
                if self.ledger is not None:
                    axis = self.cfg.strategy_params.get("axis", "DP")
                    self.ledger.record_delivered(
                        step, rank, self._bucket_of(rank, axis), ids)
                if tel.enabled:
                    tokens = int(sum((b.segment_ids > 0).sum()
                                     for b in view["bins"]))
                    tel.inc("delivered_views_total", 1.0, rank=rank)
                    tel.inc("rank_tokens_total", tokens, rank=rank)
                    with self._lock:
                        new = ids - self._delivered_ids
                        self._delivered_ids |= new
                    if new:
                        tel.inc("delivered_samples_total", len(new))
        tel.observe("get_batch_seconds", time.perf_counter() - t0,
                    rank=rank)
        return view

    def step_done(self, step: int, metrics: Optional[dict] = None):
        """Call once per completed train step: checkpoints + shadow sync.
        ``metrics`` (e.g. loss, grad_norm) feed the adaptive mixture
        schedule AND land in the registry as ``train_metric`` gauges."""
        tel = self.telemetry
        with tel.span("overlord.step_done", step=step):
            if metrics:
                try:
                    self.planner.cast("observe", step, metrics)
                except Exception:
                    pass   # planner mid-recovery: metrics still recorded
                if tel.enabled:
                    for k, v in metrics.items():
                        if isinstance(v, (int, float)) \
                                and not isinstance(v, bool):
                            tel.set_gauge("train_metric", float(v),
                                          metric=k)
            if tel.enabled:
                tel.inc("train_steps_total")
                tel.set_gauge("train_step", float(step))
            self.store.maybe_save("planner", "planner", step, self.planner)
            for name, h in list(self.loaders.items()):
                self.store.maybe_save("loader", name, step, h)
                if self.shadow_mgr:
                    self.shadow_mgr.sync(name, h, step=step)
            # constructor state is tiny (counters), so it rides the
            # planner's every-step cadence rather than the loaders'
            for b, h in list(self.constructors.items()):
                self.store.maybe_save("planner", f"constructor:{b}",
                                      step, h)
            if self.ledger is not None:
                # mirror quarantines so verify() accounts them (idempotent)
                for it in self.dlq.items():
                    self.ledger.record_quarantined(
                        it["sample_id"], it["source"], it["reason"])
            if self.cfg.checkpoint_dir \
                    and step % max(self.cfg.manifest_every, 1) == 0:
                # atomic commit point for job-level recovery.  The cut is
                # captured ON the planner's mailbox thread, BETWEEN plans:
                # blobs saved from this thread would race the plan-ahead
                # pipeline and silently include pops for steps beyond the
                # manifest's label (docs/FAULT_TOLERANCE.md runbook).
                # Actor state rides the differential loader cadence; the
                # planner slice + ledger commit every manifest_every step.
                with tel.span("recovery.commit_manifest", step=step):
                    include = step % max(self.cfg.loader_ckpt_every,
                                         1) == 0
                    epoch = None
                    try:
                        cut = self.planner.call(
                            "capture_cut", include, timeout=60,
                            retry=self.cfg.retry)
                        epoch = self.store.commit_cut(step, cut)
                    except Exception:
                        pass   # planner mid-recovery: next step commits
                if tel.enabled and epoch is not None:
                    tel.inc("recovery_manifests_total")
                    tel.set_gauge("recovery_committed_epoch", float(epoch))

    # ------------------------------------------------------ job recovery
    def resume(self, store: Optional[CheckpointStore] = None):
        """Restart the dataloader JOB from the newest consistent on-disk
        epoch (§6.1 deployment story): acquire the fence (locking any
        zombie incarnation out of future commits), rebuild the plane with
        clients deferred, restore planner/loaders/constructors/ledger from
        the manifest, roll the planner back to the manifest step, replay
        each loader's plan-history gap, then start clients at the first
        undelivered step.  Falls back to a cold ``start()`` when no
        consistent epoch exists."""
        assert not self._started
        t0 = time.perf_counter()
        tel = self.telemetry
        if store is not None:
            self.store = store
        with tel.span("recovery.resume"):
            token = self.store.acquire_fence()
            with tel.span("recovery.load_manifest"):
                man = self.store.latest_manifest()
            if man is None:
                self.start()
                self.resume_report = {
                    "cold_start": True, "epoch": None, "step": -1,
                    "fence_token": token, "restored": [], "replayed_steps": 0}
                return self
            step = int(man["step"])
            # R: the recovery line.  ``step`` is the delivery frontier
            # (last completed train step); ``frontier`` is the actor
            # cut's plan frontier.  Steps in (step, R] are served from
            # the restored constructor views — their samples were popped
            # from the loader buffers before the cut, so they cannot be
            # replanned, only restored.  Steps beyond R are replanned
            # deterministically from the restored buffers.
            rline = max(step, int(man.get("frontier", step)))
            self.store.adopt_cut(man)
            self.start(spawn_clients=False)
            restored = []
            with tel.span("recovery.restore", step=step,
                          epoch=man["epoch"]):
                ck = self.store.load_from_manifest(man, "planner")
                if ck is not None:
                    self.planner.call("restore_state", ck["state"],
                                      retry=self.cfg.retry)
                    restored.append("planner")
                # discard plan-ahead state beyond the recovery line:
                # those steps' deposits died with the old process and
                # will be replanned from the restored buffers
                self.planner.call("rollback_to", rline,
                                  retry=self.cfg.retry)
                loader_since: dict[str, int] = {}
                for name, h in list(self.loaders.items()):
                    ck = self.store.load_from_manifest(man, name)
                    if ck is None:
                        continue
                    try:
                        # perf: serial ok — recovery path, not step path
                        h.call("restore_state", ck["state"],
                               retry=self.cfg.retry)
                        restored.append(name)
                        loader_since[name] = int(ck["step"])
                    except Exception:
                        pass   # fresh loader; replay from -1 still converges
                for b, h in list(self.constructors.items()):
                    ck = self.store.load_from_manifest(
                        man, f"constructor:{b}")
                    if ck is None:
                        continue
                    try:
                        # perf: serial ok — recovery path, not step path
                        h.call("restore_state", ck["state"],
                               retry=self.cfg.retry)
                        restored.append(f"constructor:{b}")
                    except Exception:
                        pass
                if self.ledger is not None:
                    snap = self.store.load_ledger(man)
                    if snap is not None:
                        self.ledger.restore(snap)
                        with self._lock:
                            self._delivered_ids = \
                                self.ledger.delivered_ids()
                        restored.append("ledger")
            replayed = 0
            with tel.span("recovery.replay", step=step):
                for name, h in list(self.loaders.items()):
                    # perf: serial ok — recovery path
                    since = loader_since.get(name, -1)
                    self._replay_since(h, name, since)
                    replayed = max(replayed, rline - since)
            self._spawn_clients(start_step=step + 1)
        elapsed = time.perf_counter() - t0
        if tel.enabled:
            tel.inc("recovery_resumes_total")
            tel.set_gauge("recovery_epoch", float(man["epoch"]))
            tel.set_gauge("recovery_replayed_steps", float(replayed))
            tel.observe("recovery_resume_seconds", elapsed)
        self.resume_report = {
            "cold_start": False, "epoch": man["epoch"], "step": step,
            "frontier": rline, "fence_token": token, "restored": restored,
            "replayed_steps": replayed, "resume_s": elapsed}
        return self

    def simulate_process_death(self):
        """Abrupt whole-job crash: every actor killed with mail dropped,
        no supervision callbacks (the supervisor dies with the process),
        clients torn down.  The only way back is ``resume()`` on a fresh
        Overlord — exactly the process-death chaos mode's contract."""
        self.runtime.terminate()
        for c in self.clients.values():
            c.close()
        self.clients.clear()
        self._started = False

    # ------------------------------------------------------ introspection
    def memory_report(self) -> dict:
        rep = self.runtime.memory_report()
        out = {
            "loaders": sum(v for k, v in rep.items()
                           if k.startswith("loader:")
                           and "::shadow" not in k),
            "shadows": sum(v for k, v in rep.items() if "::shadow" in k),
            "constructors": sum(v for k, v in rep.items()
                                if k.startswith("constructor:")),
            "planner": rep.get("planner", 0),
        }
        out["total_ex_shadows"] = (out["loaders"] + out["constructors"]
                                   + out["planner"])
        return out

    def diagnostics(self) -> list[dict]:
        return self.planner.call("diagnostics", retry=self.cfg.retry)

    def resilience_report(self) -> dict:
        """One view over every hardening surface: checkpoint-save failures,
        shadow staleness, quarantined samples, per-source breaker state."""
        health = {}
        for name, h in list(self.loaders.items()):
            if "::shadow" in name or not h.alive:
                continue
            try:
                # perf: serial ok — operator introspection, not step path
                health[name] = h.call("health", timeout=10)
            except Exception:
                health[name] = {"source": "?", "breaker": "unreachable"}
        return {
            "checkpoints": self.store.stats(),
            "shadows": self.shadow_mgr.stats() if self.shadow_mgr else {},
            "dlq": self.dlq.stats(),
            "loaders": health,
            "recoveries": len(self.recovery_log),
        }

    # ------------------------------------------------- telemetry surfaces
    def telemetry_report(self) -> dict:
        """The unified observability view: metric snapshot + memory +
        resilience + plan diagnostics + delivery accounting, one call.
        Supersedes stitching memory_report()/diagnostics()/
        resilience_report() together by hand (those remain available)."""
        tel = self.telemetry
        if tel.enabled:
            for name, h in self.runtime.actors().items():
                if h.alive:
                    tel.set_gauge("actor_mailbox_depth",
                                  float(h.mailbox_depth), actor=name)
            dlq = self.dlq.stats()
            tel.set_gauge("dlq_held", float(dlq["held"]))
            tel.set_gauge("dlq_total", float(dlq["total"]))
        per_rank = {
            dict(key).get("rank", "?"): c.value
            for (name, key), c in tel.registry.series()["counters"].items()
            if name == "rank_tokens_total"}
        vals = list(per_rank.values())
        imbalance = (max(vals) / (sum(vals) / len(vals))
                     if vals and sum(vals) > 0 else 1.0)
        if tel.enabled and vals:
            tel.set_gauge("rank_token_imbalance", imbalance)
        try:
            diag = self.diagnostics()
        except Exception:
            diag = []
        return {
            "enabled": tel.enabled,
            "metrics": tel.snapshot(),
            "memory": self.memory_report(),
            "resilience": self.resilience_report(),
            "diagnostics": diag,
            "delivery": {
                "delivered_samples": int(tel.registry.counter_value(
                    "delivered_samples_total")),
                "per_rank_tokens": per_rank,
                "token_imbalance": imbalance,
            },
            "spans": {"finished": len(tel.tracer),
                      "dropped": tel.tracer.dropped},
        }

    def prometheus_dump(self) -> str:
        """Prometheus text exposition of the full registry."""
        return render_prometheus(self.telemetry.registry)

    def chrome_trace(self) -> dict:
        """chrome://tracing / Perfetto JSON of the finished spans."""
        return chrome_trace(self.telemetry.tracer)

    def write_chrome_trace(self, path) -> None:
        write_chrome_trace(path, self.telemetry.tracer)

    # --------------------------------------------------- fault injection
    def inject_loader_failures(self, n: int = 1):
        names = [k for k in self.loaders if "::shadow" not in k][:n]
        for name in names:
            self.loaders[name].kill()
        return names

    def inject_planner_failure(self):
        self.planner.kill()

    def shutdown(self):
        for c in self.clients.values():
            c.close()
        self.runtime.shutdown()
