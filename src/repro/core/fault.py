"""Non-interrupted fault tolerance (§6.1, Fig. 11/16).

  * CheckpointStore — persistent store with per-actor DIFFERENTIAL
    frequencies: the Planner journals every step (small state), Source
    Loaders every ``loader_every`` steps (large buffers), and the gap is
    covered by replaying the Planner's plan history against the restored
    loader ("replay window").
  * ShadowManager — hot-standby shadow loaders kept in sync by periodic
    state mirroring; on failure the supervisor promotes the shadow
    immediately (no storage round-trip), so data delivery never pauses.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from typing import Callable, Optional

from repro.core.actors import Actor, ActorHandle, ActorRuntime
from repro.core.source_loader import SourceLoader


class CheckpointStore:
    def __init__(self, root: Optional[str] = None,
                 planner_every: int = 1, loader_every: int = 8,
                 restore_delay_s: float = 0.0):
        self.root = root
        self.planner_every = planner_every
        self.loader_every = loader_every
        # models remote persistent-store read latency (benchmarks inject a
        # realistic value; production would see storage RTT here)
        self.restore_delay_s = restore_delay_s
        self._mem: dict[str, tuple[int, bytes]] = {}
        self._lock = threading.Lock()
        if root:
            os.makedirs(root, exist_ok=True)

    def _should(self, kind: str, step: int) -> bool:
        every = self.planner_every if kind == "planner" else \
            self.loader_every
        return step % max(every, 1) == 0

    def maybe_save(self, kind: str, name: str, step: int,
                   handle: ActorHandle) -> bool:
        if not self._should(kind, step) or not handle.alive:
            return False
        try:
            state = handle.call("checkpoint_state", timeout=10)
        except Exception:
            return False
        blob = pickle.dumps({"step": step, "state": state})
        with self._lock:
            self._mem[name] = (step, blob)
        if self.root:
            tmp = os.path.join(self.root, f".{name}.tmp")
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, os.path.join(self.root, f"{name}.ckpt"))
        return True

    def load(self, name: str) -> Optional[dict]:
        if self.restore_delay_s:
            time.sleep(self.restore_delay_s)
        with self._lock:
            if name in self._mem:
                return pickle.loads(self._mem[name][1])
        if self.root:
            path = os.path.join(self.root, f"{name}.ckpt")
            if os.path.exists(path):
                with open(path, "rb") as f:
                    return pickle.loads(f.read())
        return None

    def checkpointed_step(self, name: str) -> int:
        with self._lock:
            if name in self._mem:
                return self._mem[name][0]
        return -1


class ShadowManager:
    """Maintains one warm shadow per active Source Loader.

    Sync model: after every plan the orchestrator calls ``sync(name)``,
    which mirrors the active loader's checkpoint state into the shadow
    (cheap: in-process actor message).  On failure, ``promote`` swaps the
    shadow in — it already holds the buffer, so the next plan proceeds
    without touching storage.
    """

    def __init__(self, runtime: ActorRuntime,
                 make_loader: Callable[[str], SourceLoader]):
        self.runtime = runtime
        self.make_loader = make_loader
        self.shadows: dict[str, ActorHandle] = {}
        self.promotions: list[dict] = []

    def ensure_shadow(self, name: str) -> ActorHandle:
        if name in self.shadows and self.shadows[name].alive:
            return self.shadows[name]
        h = self.runtime.spawn(f"{name}::shadow", self.make_loader(name))
        self.shadows[name] = h
        return h

    def sync(self, name: str, active: ActorHandle):
        sh = self.shadows.get(name)
        if sh is None or not sh.alive or not active.alive:
            return
        try:
            state = active.call("checkpoint_state", timeout=10)
            sh.cast("restore_state", state)
        except Exception:
            pass

    def promote(self, name: str) -> Optional[ActorHandle]:
        sh = self.shadows.pop(name, None)
        if sh is None or not sh.alive:
            return None
        self.runtime.reassign(f"{name}::shadow", name)
        self.promotions.append({"name": name, "time": time.time()})
        return sh


def shadow_memory_bytes(mgr: ShadowManager) -> int:
    """Reported separately — the paper excludes shadow memory from the
    fair comparison (§7.1) but we surface it for completeness."""
    return sum(h.memory_bytes() for h in mgr.shadows.values() if h.alive)
