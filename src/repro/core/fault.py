"""Non-interrupted fault tolerance (§6.1, Fig. 11/16) + durable job
recovery (§6.1 deployment story: restart the dataloader JOB without
re-reading delivered samples).

  * CheckpointStore — persistent store with per-actor DIFFERENTIAL
    frequencies: the Planner journals every step (small state), Source
    Loaders every ``loader_every`` steps (large buffers), and the gap is
    covered by replaying the Planner's plan history against the restored
    loader ("replay window").

    On disk the store is CRASH-CONSISTENT: every blob is framed with a
    magic/version/CRC32 header (a truncated or bit-rotted ``.ckpt`` is
    rejected, never unpickled), and a run's state is only visible to a
    resuming job once an epoch MANIFEST — written atomically via
    tmp+rename — references the blobs.  Manifests carry a monotonic job
    epoch; ``latest_manifest()`` walks epochs newest-first and falls back
    past any inconsistent epoch.  Old epochs and unreferenced blobs are
    garbage-collected (``keep_epochs`` retained).

    Writes are FENCED: each (re)starting job acquires a monotonic token
    from the ``FENCE`` file; a zombie pre-crash incarnation still holding
    an older token can never commit a manifest over the new job's state
    (its commits are refused and counted in ``stats()``).

  * ShadowManager — hot-standby shadow loaders kept in sync by periodic
    state mirroring; on failure the supervisor promotes the shadow
    immediately (no storage round-trip), so data delivery never pauses.

Failures on either path are COUNTED and surfaced through ``stats()``
(save/load failures per actor, fenced writes, manifest fallbacks,
shadow-sync staleness in steps) — a save or sync that silently fails is
how recovery quietly rots, so the chaos harness asserts on these
counters.  Layout and fencing semantics: docs/FAULT_TOLERANCE.md.
"""
from __future__ import annotations

import collections
import json
import os
import pickle
import re
import struct
import threading
import time
import zlib
from typing import Callable, Optional

from repro.core.actors import Actor, ActorHandle, ActorRuntime
from repro.core.source_loader import SourceLoader

#: manifest document schema version (bump on incompatible layout change)
MANIFEST_VERSION = 1

# blob framing: magic | version | crc32(payload) | len(payload) | payload
_BLOB_MAGIC = b"MSDC"
_BLOB_VERSION = 1
_HEADER = struct.Struct("<4sBIQ")
_MANIFEST_RE = re.compile(r"^epoch-(\d{8})\.manifest\.json$")
_BLOB_STEP_RE = re.compile(r"@(\d+)\.t\d+\.ckpt$")

#: manifest key for the persisted DeliveryLedger snapshot
LEDGER_KEY = "__ledger__"


class CheckpointCorruption(ValueError):
    """A checkpoint blob failed framing / checksum verification."""


def frame_blob(payload: bytes) -> bytes:
    """Self-verifying on-disk framing for checkpoint payloads."""
    return _HEADER.pack(_BLOB_MAGIC, _BLOB_VERSION,
                        zlib.crc32(payload), len(payload)) + payload


def unframe_blob(data: bytes) -> bytes:
    """Inverse of ``frame_blob``; raises CheckpointCorruption on a
    truncated, foreign, or bit-rotted blob instead of unpickling it."""
    if len(data) < _HEADER.size:
        raise CheckpointCorruption(
            f"truncated blob header ({len(data)} bytes)")
    magic, version, crc, length = _HEADER.unpack_from(data)
    if magic != _BLOB_MAGIC:
        raise CheckpointCorruption(f"bad blob magic {magic!r}")
    if version != _BLOB_VERSION:
        raise CheckpointCorruption(f"unknown blob version {version}")
    payload = data[_HEADER.size:]
    if len(payload) != length:
        raise CheckpointCorruption(
            f"truncated blob payload ({len(payload)} of {length} bytes)")
    if zlib.crc32(payload) != crc:
        raise CheckpointCorruption("blob checksum mismatch (bit rot?)")
    return payload


class CheckpointStore:
    def __init__(self, root: Optional[str] = None,
                 planner_every: int = 1, loader_every: int = 8,
                 restore_delay_s: float = 0.0, keep_epochs: int = 3):
        self.root = root
        self.planner_every = planner_every
        self.loader_every = loader_every
        self.keep_epochs = max(int(keep_epochs), 1)
        # models remote persistent-store read latency (benchmarks inject a
        # realistic value; production would see storage RTT here)
        self.restore_delay_s = restore_delay_s
        self._mem: dict[str, tuple[int, bytes]] = {}
        self._lock = threading.Lock()
        self._saves: collections.Counter = collections.Counter()
        self._save_failures: collections.Counter = collections.Counter()
        self._load_failures: collections.Counter = collections.Counter()
        self._last_failure: dict[str, str] = {}
        # durable-manifest state (all guarded by _lock)
        self._blob_index: dict[str, dict] = {}   # name -> manifest entry
        self._cut_entries: dict[str, dict] = {}  # last consistent actor cut
        self._cut_frontier = -1                  # plan frontier of that cut
        self._fence_token: Optional[int] = None
        self._epoch = 0                          # last epoch committed
        self._fenced_writes = 0
        self._manifests_committed = 0
        self._manifest_fallbacks = 0
        self._manifest_cache: Optional[dict] = None
        if root:
            os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------ fencing
    def _fence_path(self) -> str:
        return os.path.join(self.root, "FENCE")

    def _read_fence(self) -> int:
        try:
            with open(self._fence_path(), encoding="utf-8") as f:
                return int(json.load(f)["token"])
        except (OSError, ValueError, KeyError):
            return 0

    def acquire_fence(self) -> int:
        """Claim the job fence: bump the on-disk token so any OLDER
        incarnation still running is fenced out of future commits.
        Idempotent per store instance (a store acquires at most once)."""
        if not self.root:
            with self._lock:
                if self._fence_token is None:
                    self._fence_token = 0
                return self._fence_token
        with self._lock:
            if self._fence_token is not None:
                return self._fence_token
            token = self._read_fence() + 1
            tmp = self._fence_path() + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump({"token": token, "acquired_at": time.time()}, f)
            os.replace(tmp, self._fence_path())
            self._fence_token = token
            self._epoch = max((e for e, _ in self._manifest_files()),
                              default=0)
            return token

    @property
    def fence_token(self) -> Optional[int]:
        return self._fence_token

    def is_fenced(self) -> bool:
        """True when a NEWER incarnation holds the fence: this store must
        no longer commit (zombie protection for the resumed job)."""
        if not self.root or self._fence_token is None:
            return False
        return self._read_fence() > self._fence_token

    # ---------------------------------------------------------- blob I/O
    def _blobs_dir(self) -> str:
        return os.path.join(self.root, "blobs")

    def _blob_name(self, name: str, step: int) -> str:
        safe = name.replace(os.sep, "_")
        token = self._fence_token or 0
        return f"{safe}@{step}.t{token}.ckpt"

    def _write_blob(self, name: str, step: int, payload: bytes,
                    label_step: Optional[int] = None) -> dict:
        """Write one framed blob atomically; returns its manifest entry.
        Blob filenames embed the fence token, so a zombie incarnation's
        writes can never clobber a blob the new job's manifest names.
        ``label_step`` decouples the entry's semantic step (replay-window
        sizing) from the filename step (uniqueness across commits)."""
        rel = os.path.join("blobs", self._blob_name(name, step))
        path = os.path.join(self.root, rel)
        os.makedirs(self._blobs_dir(), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(frame_blob(payload))
        os.replace(tmp, path)
        entry = {"step": int(step if label_step is None else label_step),
                 "blob": rel,
                 "crc32": zlib.crc32(payload), "size": len(payload)}
        with self._lock:
            self._blob_index[name] = entry
        return entry

    def _read_entry_payload(self, entry: dict) -> bytes:
        path = os.path.join(self.root, entry["blob"])
        with open(path, "rb") as f:
            payload = unframe_blob(f.read())
        if zlib.crc32(payload) != entry.get("crc32") \
                or len(payload) != entry.get("size"):
            raise CheckpointCorruption(
                f"{entry['blob']}: blob does not match its manifest entry")
        return payload

    def _count_load_failure(self, name: str, exc: BaseException) -> None:
        with self._lock:
            self._load_failures[name] += 1
            self._last_failure[name] = f"{type(exc).__name__}: {exc}"

    def _decode(self, name: str, data: bytes) -> Optional[dict]:
        """Framed blob -> payload, else legacy raw pickle; corruption is
        counted as a load failure and yields None (caller falls back)."""
        try:
            if data[:len(_BLOB_MAGIC)] == _BLOB_MAGIC:
                return pickle.loads(unframe_blob(data))
            return pickle.loads(data)
        except Exception as e:   # truncated pickle, bad frame, bit rot
            self._count_load_failure(name, e)
            return None

    # ---------------------------------------------------------- manifests
    def _manifest_files(self) -> list[tuple[int, str]]:
        """(epoch, path) for every manifest on disk, ascending."""
        if not self.root or not os.path.isdir(self.root):
            return []
        out = []
        for fn in os.listdir(self.root):
            m = _MANIFEST_RE.match(fn)
            if m:
                out.append((int(m.group(1)), os.path.join(self.root, fn)))
        return sorted(out)

    def _verify_manifest(self, path: str) -> Optional[dict]:
        """Parse + verify one manifest: every referenced blob must exist
        and pass its checksum, or the whole epoch is inconsistent."""
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return None
        if doc.get("version") != MANIFEST_VERSION \
                or not isinstance(doc.get("actors"), dict):
            return None
        entries = list(doc["actors"].values())
        if doc.get("ledger"):
            entries.append(doc["ledger"])
        for entry in entries:
            try:
                self._read_entry_payload(entry)
            except (CheckpointCorruption, OSError, KeyError, TypeError):
                return None
        return doc

    def latest_manifest(self) -> Optional[dict]:
        """Newest CONSISTENT manifest.  Inconsistent epochs (corrupt
        manifest JSON or any bad blob) are skipped — recovery falls back
        to the last good epoch instead of crashing — and counted."""
        with self._lock:
            if self._manifest_cache is not None:
                return self._manifest_cache
        for _epoch, path in reversed(self._manifest_files()):
            doc = self._verify_manifest(path)
            if doc is not None:
                with self._lock:
                    self._manifest_cache = doc
                return doc
            with self._lock:
                self._manifest_fallbacks += 1
                self._last_failure["__manifest__"] = \
                    f"CheckpointCorruption: inconsistent epoch at {path}"
        return None

    def _fence_ok(self) -> bool:
        if self._fence_token is None:
            self.acquire_fence()
        if self.is_fenced():
            with self._lock:
                self._fenced_writes += 1
                self._last_failure["__manifest__"] = (
                    "FencedWrite: a newer incarnation holds the fence "
                    f"(mine={self._fence_token}, disk={self._read_fence()})")
            return False
        return True

    def _write_ledger_blob(self, step: int,
                           ledger_state: Optional[object]) -> Optional[dict]:
        if ledger_state is None:
            return None
        try:
            return self._write_blob(LEDGER_KEY, step,
                                    pickle.dumps(ledger_state))
        except OSError as e:
            with self._lock:
                self._save_failures[LEDGER_KEY] += 1
                self._last_failure[LEDGER_KEY] = f"{type(e).__name__}: {e}"
            return None

    def _commit_epoch(self, step: int, actors: dict,
                      ledger_entry: Optional[dict],
                      frontier: int) -> Optional[int]:
        """The commit point: one atomic manifest rename.  A crash before
        it leaves the previous epoch authoritative; after it,
        ``latest_manifest`` returns this one."""
        with self._lock:
            epoch = self._epoch + 1
            token = self._fence_token
        doc = {"version": MANIFEST_VERSION, "epoch": epoch,
               "step": int(step), "frontier": int(frontier),
               "fence_token": token, "committed_at": time.time(),
               "actors": actors, "ledger": ledger_entry}
        path = os.path.join(self.root, f"epoch-{epoch:08d}.manifest.json")
        try:
            tmp = path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except OSError as e:
            with self._lock:
                self._save_failures["__manifest__"] += 1
                self._last_failure["__manifest__"] = \
                    f"{type(e).__name__}: {e}"
            return None
        with self._lock:
            self._epoch = epoch
            self._manifests_committed += 1
            self._manifest_cache = doc
        self._gc()
        return epoch

    def commit_manifest(self, step: int,
                        ledger_state: Optional[object] = None
                        ) -> Optional[int]:
        """Commit the current ``maybe_save`` blob set as a new job epoch.
        ``ledger_state`` (a DeliveryLedger snapshot) is persisted
        alongside, so exactly-once accounting spans process restarts.
        Returns the epoch, or None when fenced / diskless.  NOTE: blobs
        saved from the Overlord thread race the plan-ahead pipeline; the
        Overlord commits planner-consistent ``commit_cut`` epochs
        instead, this variant serves direct store users and tests."""
        if not self.root or not self._fence_ok():
            return None
        ledger_entry = self._write_ledger_blob(step, ledger_state)
        with self._lock:
            actors = {n: dict(e) for n, e in self._blob_index.items()
                      if n != LEDGER_KEY}
        frontier = max([e["step"] for e in actors.values()], default=step)
        return self._commit_epoch(step, actors, ledger_entry, frontier)

    def commit_cut(self, step: int, cut: dict) -> Optional[int]:
        """Commit a ``Planner.capture_cut`` as a new job epoch.  Actors
        absent from this cut (differential frequency: loader/constructor
        blobs are captured less often than the planner's) keep their
        entries from the previous cut — inherited via ``adopt_cut`` on
        resume — so every manifest references a complete, mutually
        consistent blob set."""
        if not self.root or not self._fence_ok():
            return None
        frontier = int(cut.get("frontier", step))
        new_entries = {}
        try:
            new_entries["planner"] = self._write_blob(
                "planner", step,
                pickle.dumps({"step": frontier, "state": cut["planner"]}),
                label_step=frontier)
            for name, state in (cut.get("actors") or {}).items():
                new_entries[name] = self._write_blob(
                    name, step,
                    pickle.dumps({"step": frontier, "state": state}),
                    label_step=frontier)
        except OSError as e:
            with self._lock:
                self._save_failures["__manifest__"] += 1
                self._last_failure["__manifest__"] = \
                    f"{type(e).__name__}: {e}"
            return None
        with self._lock:
            if cut.get("actors"):
                self._cut_entries = {n: dict(e)
                                     for n, e in new_entries.items()
                                     if n != "planner"}
                self._cut_frontier = frontier
            actors = {n: dict(e) for n, e in self._cut_entries.items()}
            cut_frontier = self._cut_frontier \
                if self._cut_entries else frontier
        actors["planner"] = new_entries["planner"]
        ledger_entry = self._write_ledger_blob(step, cut.get("ledger"))
        return self._commit_epoch(step, actors, ledger_entry, cut_frontier)

    def adopt_cut(self, manifest: dict) -> None:
        """Carry a resumed-from manifest's actor blob set forward: until
        this incarnation captures its own actor cut, planner-only commits
        keep referencing the inherited (still consistent, still on disk)
        blobs, so no epoch is ever missing loader state."""
        with self._lock:
            self._cut_entries = {
                n: dict(e) for n, e in manifest.get("actors", {}).items()
                if n != "planner"}
            self._cut_frontier = int(
                manifest.get("frontier", manifest.get("step", -1)))

    def _gc(self) -> None:
        """Retention: keep the newest ``keep_epochs`` manifests plus every
        blob any retained manifest (or the in-flight index) references."""
        files = self._manifest_files()
        keep, drop = files[-self.keep_epochs:], files[:-self.keep_epochs]
        referenced: set[str] = set()
        for _epoch, path in keep:
            try:
                with open(path, encoding="utf-8") as f:
                    doc = json.load(f)
            except (OSError, ValueError):
                continue
            entries = list(doc.get("actors", {}).values())
            if doc.get("ledger"):
                entries.append(doc["ledger"])
            for e in entries:
                referenced.add(os.path.basename(e.get("blob", "")))
        with self._lock:
            referenced |= {os.path.basename(e["blob"])
                           for e in self._blob_index.values()}
            referenced |= {os.path.basename(e["blob"])
                           for e in self._cut_entries.values()}
        for _epoch, path in drop:
            try:
                os.remove(path)
            except OSError:
                pass
        bdir = self._blobs_dir()
        if os.path.isdir(bdir):
            for fn in os.listdir(bdir):
                if fn.endswith(".ckpt") and fn not in referenced:
                    try:
                        os.remove(os.path.join(bdir, fn))
                    except OSError:
                        pass

    # ------------------------------------------------------------- saves
    def _should(self, kind: str, step: int) -> bool:
        every = self.planner_every if kind == "planner" else \
            self.loader_every
        return step % max(every, 1) == 0

    def maybe_save(self, kind: str, name: str, step: int,
                   handle: ActorHandle) -> bool:
        if not self._should(kind, step) or not handle.alive:
            return False
        try:
            state = handle.call("checkpoint_state", timeout=10)
            blob = pickle.dumps({"step": step, "state": state})
        except Exception as e:
            # a missed save widens the replay window; count it so
            # operators (and the chaos soak) can see recovery debt grow
            with self._lock:
                self._save_failures[name] += 1
                self._last_failure[name] = f"{type(e).__name__}: {e}"
            return False
        with self._lock:
            self._mem[name] = (step, blob)
            self._saves[name] += 1
        if self.root:
            if self.is_fenced():
                # zombie incarnation: its state must never reach disk
                with self._lock:
                    self._fenced_writes += 1
                return False
            try:
                self._write_blob(name, step, blob)
            except OSError as e:
                with self._lock:
                    self._save_failures[name] += 1
                    self._last_failure[name] = f"{type(e).__name__}: {e}"
                return False
        return True

    # ------------------------------------------------------------- loads
    def load(self, name: str) -> Optional[dict]:
        """Newest known state for ``name``: memory, then the newest
        consistent on-disk manifest, then orphan/legacy ``.ckpt`` files.
        Corrupt data is counted in ``stats()['load_failures']`` and
        falls back instead of raising."""
        if self.restore_delay_s:
            time.sleep(self.restore_delay_s)
        with self._lock:
            if name in self._mem:
                blob = self._mem[name][1]
            else:
                blob = None
        if blob is not None:
            out = self._decode(name, blob)
            if out is not None:
                return out
        return self._load_from_disk(name)

    def _load_from_disk(self, name: str) -> Optional[dict]:
        if not self.root:
            return None
        man = self.latest_manifest()
        if man is not None and name in man["actors"]:
            out = self.load_from_manifest(man, name)
            if out is not None:
                return out
        if man is None:
            # no committed epoch at all: trust self-verifying orphan
            # blobs (maybe_save without commit_manifest)
            entry = self._newest_orphan_blob(name)
            if entry is not None:
                out = self._load_entry(name, entry, verify_index=False)
                if out is not None:
                    return out
        # legacy flat file (pre-manifest layout)
        path = os.path.join(self.root, f"{name}.ckpt")
        if os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    return self._decode(name, f.read())
            except OSError as e:
                self._count_load_failure(name, e)
        return None

    def load_from_manifest(self, manifest: dict,
                           name: str) -> Optional[dict]:
        """Load one actor's blob as referenced by ``manifest``.  A blob
        that fails verification is counted and yields None."""
        entry = (manifest or {}).get("actors", {}).get(name)
        if entry is None:
            return None
        if self.restore_delay_s:   # simulated persistent-store read RTT
            time.sleep(self.restore_delay_s)
        return self._load_entry(name, entry)

    def load_ledger(self, manifest: dict) -> Optional[object]:
        """The DeliveryLedger snapshot committed with ``manifest``."""
        entry = (manifest or {}).get("ledger")
        if not entry:
            return None
        return self._load_entry(LEDGER_KEY, entry)

    def _load_entry(self, name: str, entry: dict,
                    verify_index: bool = True) -> Optional[dict]:
        try:
            if verify_index:
                payload = self._read_entry_payload(entry)
            else:
                path = os.path.join(self.root, entry["blob"])
                with open(path, "rb") as f:
                    payload = unframe_blob(f.read())
            return pickle.loads(payload)
        except Exception as e:
            self._count_load_failure(name, e)
            return None

    def _newest_orphan_blob(self, name: str) -> Optional[dict]:
        bdir = self._blobs_dir()
        if not os.path.isdir(bdir):
            return None
        safe = name.replace(os.sep, "_")
        best: Optional[tuple[int, str]] = None
        for fn in os.listdir(bdir):
            if not fn.startswith(f"{safe}@"):
                continue
            m = _BLOB_STEP_RE.search(fn)
            if m and (best is None or int(m.group(1)) > best[0]):
                best = (int(m.group(1)), fn)
        if best is None:
            return None
        return {"step": best[0], "blob": os.path.join("blobs", best[1])}

    def checkpointed_step(self, name: str) -> int:
        """Step of the newest checkpoint for ``name`` — consults DISK
        (manifest chain, then orphan/legacy files) when this process has
        no in-memory record, so a restarted job sizes its replay window
        from what actually survived, not from -1."""
        with self._lock:
            if name in self._mem:
                return self._mem[name][0]
            if name in self._blob_index:
                return self._blob_index[name]["step"]
        if not self.root:
            return -1
        man = self.latest_manifest()
        if man is not None and name in man["actors"]:
            return int(man["actors"][name]["step"])
        entry = self._newest_orphan_blob(name)
        if entry is not None:
            return int(entry["step"])
        path = os.path.join(self.root, f"{name}.ckpt")
        if os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    doc = self._decode(name, f.read())
            except OSError:
                doc = None
            if isinstance(doc, dict) and "step" in doc:
                return int(doc["step"])
        return -1

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            steps = {n: e["step"] for n, e in self._blob_index.items()
                     if n != LEDGER_KEY}
            steps.update({n: s for n, (s, _) in self._mem.items()})
            return {
                "saves": dict(self._saves),
                "save_failures": dict(self._save_failures),
                "load_failures": dict(self._load_failures),
                "last_failure": dict(self._last_failure),
                "checkpointed_steps": steps,
                "epoch": self._epoch,
                "fence_token": self._fence_token,
                "fenced_writes": self._fenced_writes,
                "manifests_committed": self._manifests_committed,
                "manifest_fallbacks": self._manifest_fallbacks,
            }


class ShadowManager:
    """Maintains one warm shadow per active Source Loader.

    Sync model: after every plan the orchestrator calls ``sync(name)``,
    which mirrors the active loader's checkpoint state into the shadow
    (cheap: in-process actor message).  On failure, ``promote`` swaps the
    shadow in — it already holds the buffer, so the next plan proceeds
    without touching storage; plans issued AFTER the last successful sync
    must then be replayed against the promoted shadow (the supervisor
    does this with the Planner's history window) or the same samples
    would be delivered twice.
    """

    def __init__(self, runtime: ActorRuntime,
                 make_loader: Callable[[str], SourceLoader]):
        self.runtime = runtime
        self.make_loader = make_loader
        self.shadows: dict[str, ActorHandle] = {}
        self.promotions: list[dict] = []
        self._synced_step: dict[str, int] = {}
        self._sync_failures: collections.Counter = collections.Counter()
        self._last_step_seen = -1

    def ensure_shadow(self, name: str) -> ActorHandle:
        if name in self.shadows and self.shadows[name].alive:
            return self.shadows[name]
        h = self.runtime.spawn(f"{name}::shadow", self.make_loader(name))
        self.shadows[name] = h
        return h

    def sync(self, name: str, active: ActorHandle,
             step: Optional[int] = None) -> bool:
        if step is not None:
            self._last_step_seen = max(self._last_step_seen, step)
        sh = self.shadows.get(name)
        if sh is None or not sh.alive or not active.alive:
            return False
        try:
            state = active.call("checkpoint_state", timeout=10)
            sh.cast("restore_state", state)
        except Exception:
            # shadow goes stale by one sync period; count it — staleness
            # is exactly the replay debt promote() inherits
            self._sync_failures[name] += 1
            return False
        if step is not None:
            self._synced_step[name] = step
        return True

    def synced_step(self, name: str) -> int:
        """Last step whose state the shadow is known to hold (-1: never)."""
        return self._synced_step.get(name, -1)

    def promote(self, name: str) -> Optional[ActorHandle]:
        sh = self.shadows.pop(name, None)
        if sh is None or not sh.alive:
            return None
        self.runtime.reassign(f"{name}::shadow", name)
        self.promotions.append({"name": name, "time": time.time(),
                                "synced_step": self.synced_step(name)})
        # the NEXT shadow for this name starts unsynced; leaving the old
        # step here would make a second promotion replay too little
        self._synced_step.pop(name, None)
        return sh

    def stats(self) -> dict:
        staleness = {
            name: (self._last_step_seen - self._synced_step.get(name, -1))
            for name in self.shadows}
        return {
            "sync_failures": dict(self._sync_failures),
            "synced_steps": dict(self._synced_step),
            "staleness_steps": staleness,
            "promotions": len(self.promotions),
        }


def shadow_memory_bytes(mgr: ShadowManager) -> int:
    """Reported separately — the paper excludes shadow memory from the
    fair comparison (§7.1) but we surface it for completeness."""
    return sum(h.memory_bytes() for h in mgr.shadows.values() if h.alive)
