"""Non-interrupted fault tolerance (§6.1, Fig. 11/16).

  * CheckpointStore — persistent store with per-actor DIFFERENTIAL
    frequencies: the Planner journals every step (small state), Source
    Loaders every ``loader_every`` steps (large buffers), and the gap is
    covered by replaying the Planner's plan history against the restored
    loader ("replay window").
  * ShadowManager — hot-standby shadow loaders kept in sync by periodic
    state mirroring; on failure the supervisor promotes the shadow
    immediately (no storage round-trip), so data delivery never pauses.

Failures on either path are COUNTED and surfaced through ``stats()``
(save failures per actor, shadow-sync staleness in steps) — a save or
sync that silently fails is how recovery quietly rots, so the chaos
harness asserts on these counters.
"""
from __future__ import annotations

import collections
import os
import pickle
import threading
import time
from typing import Callable, Optional

from repro.core.actors import Actor, ActorHandle, ActorRuntime
from repro.core.source_loader import SourceLoader


class CheckpointStore:
    def __init__(self, root: Optional[str] = None,
                 planner_every: int = 1, loader_every: int = 8,
                 restore_delay_s: float = 0.0):
        self.root = root
        self.planner_every = planner_every
        self.loader_every = loader_every
        # models remote persistent-store read latency (benchmarks inject a
        # realistic value; production would see storage RTT here)
        self.restore_delay_s = restore_delay_s
        self._mem: dict[str, tuple[int, bytes]] = {}
        self._lock = threading.Lock()
        self._saves: collections.Counter = collections.Counter()
        self._save_failures: collections.Counter = collections.Counter()
        self._last_failure: dict[str, str] = {}
        if root:
            os.makedirs(root, exist_ok=True)

    def _should(self, kind: str, step: int) -> bool:
        every = self.planner_every if kind == "planner" else \
            self.loader_every
        return step % max(every, 1) == 0

    def maybe_save(self, kind: str, name: str, step: int,
                   handle: ActorHandle) -> bool:
        if not self._should(kind, step) or not handle.alive:
            return False
        try:
            state = handle.call("checkpoint_state", timeout=10)
            blob = pickle.dumps({"step": step, "state": state})
        except Exception as e:
            # a missed save widens the replay window; count it so
            # operators (and the chaos soak) can see recovery debt grow
            with self._lock:
                self._save_failures[name] += 1
                self._last_failure[name] = f"{type(e).__name__}: {e}"
            return False
        with self._lock:
            self._mem[name] = (step, blob)
            self._saves[name] += 1
        if self.root:
            try:
                tmp = os.path.join(self.root, f".{name}.tmp")
                with open(tmp, "wb") as f:
                    f.write(blob)
                os.replace(tmp, os.path.join(self.root, f"{name}.ckpt"))
            except OSError as e:
                with self._lock:
                    self._save_failures[name] += 1
                    self._last_failure[name] = f"{type(e).__name__}: {e}"
                return False
        return True

    def load(self, name: str) -> Optional[dict]:
        if self.restore_delay_s:
            time.sleep(self.restore_delay_s)
        with self._lock:
            if name in self._mem:
                return pickle.loads(self._mem[name][1])
        if self.root:
            path = os.path.join(self.root, f"{name}.ckpt")
            if os.path.exists(path):
                with open(path, "rb") as f:
                    return pickle.loads(f.read())
        return None

    def checkpointed_step(self, name: str) -> int:
        with self._lock:
            if name in self._mem:
                return self._mem[name][0]
        return -1

    def stats(self) -> dict:
        with self._lock:
            return {
                "saves": dict(self._saves),
                "save_failures": dict(self._save_failures),
                "last_failure": dict(self._last_failure),
                "checkpointed_steps": {n: s for n, (s, _) in
                                       self._mem.items()},
            }


class ShadowManager:
    """Maintains one warm shadow per active Source Loader.

    Sync model: after every plan the orchestrator calls ``sync(name)``,
    which mirrors the active loader's checkpoint state into the shadow
    (cheap: in-process actor message).  On failure, ``promote`` swaps the
    shadow in — it already holds the buffer, so the next plan proceeds
    without touching storage; plans issued AFTER the last successful sync
    must then be replayed against the promoted shadow (the supervisor
    does this with the Planner's history window) or the same samples
    would be delivered twice.
    """

    def __init__(self, runtime: ActorRuntime,
                 make_loader: Callable[[str], SourceLoader]):
        self.runtime = runtime
        self.make_loader = make_loader
        self.shadows: dict[str, ActorHandle] = {}
        self.promotions: list[dict] = []
        self._synced_step: dict[str, int] = {}
        self._sync_failures: collections.Counter = collections.Counter()
        self._last_step_seen = -1

    def ensure_shadow(self, name: str) -> ActorHandle:
        if name in self.shadows and self.shadows[name].alive:
            return self.shadows[name]
        h = self.runtime.spawn(f"{name}::shadow", self.make_loader(name))
        self.shadows[name] = h
        return h

    def sync(self, name: str, active: ActorHandle,
             step: Optional[int] = None) -> bool:
        if step is not None:
            self._last_step_seen = max(self._last_step_seen, step)
        sh = self.shadows.get(name)
        if sh is None or not sh.alive or not active.alive:
            return False
        try:
            state = active.call("checkpoint_state", timeout=10)
            sh.cast("restore_state", state)
        except Exception:
            # shadow goes stale by one sync period; count it — staleness
            # is exactly the replay debt promote() inherits
            self._sync_failures[name] += 1
            return False
        if step is not None:
            self._synced_step[name] = step
        return True

    def synced_step(self, name: str) -> int:
        """Last step whose state the shadow is known to hold (-1: never)."""
        return self._synced_step.get(name, -1)

    def promote(self, name: str) -> Optional[ActorHandle]:
        sh = self.shadows.pop(name, None)
        if sh is None or not sh.alive:
            return None
        self.runtime.reassign(f"{name}::shadow", name)
        self.promotions.append({"name": name, "time": time.time(),
                                "synced_step": self.synced_step(name)})
        # the NEXT shadow for this name starts unsynced; leaving the old
        # step here would make a second promotion replay too little
        self._synced_step.pop(name, None)
        return sh

    def stats(self) -> dict:
        staleness = {
            name: (self._last_step_seen - self._synced_step.get(name, -1))
            for name in self.shadows}
        return {
            "sync_failures": dict(self._sync_failures),
            "synced_steps": dict(self._synced_step),
            "staleness_steps": staleness,
            "promotions": len(self.promotions),
        }


def shadow_memory_bytes(mgr: ShadowManager) -> int:
    """Reported separately — the paper excludes shadow memory from the
    fair comparison (§7.1) but we surface it for completeness."""
    return sum(h.memory_bytes() for h in mgr.shadows.values() if h.alive)
