"""Colocated dataloader baseline (paper §2.2 / §7.2 comparison arm).

Mirrors Megatron/DDP-style per-rank loaders: EVERY data-parallel rank runs
its own loader process group that (a) opens ALL sources (replicated file
access states) and (b) runs ``workers`` worker processes each holding an
independent prefetch buffer — the two memory-scaling dimensions OVERLORD
removes.  No cross-rank planning: each rank samples its own mixture slice,
so packed-batch imbalance is whatever the draw gives (the Vanilla arm).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.mixing import MixSchedule, sample_counts
from repro.data import packing
from repro.data.storage import SourceReader
from repro.data.transforms import transform_record


@dataclasses.dataclass
class ColocatedRankLoader:
    rank: int
    readers: dict                 # source -> SourceReader (ALL sources)
    workers: int
    seq_len: int
    rows: int
    buffer_per_worker: int = 64

    def memory_bytes(self) -> int:
        access = sum(r.access_state_bytes for r in self.readers.values())
        # each worker: its own execution context + prefetch buffer of
        # transformed samples (~seq_len tokens each)
        worker = self.workers * (64 * 1024
                                 + self.buffer_per_worker
                                 * (self.seq_len * 4 + 200))
        return access + worker


class ColocatedFleet:
    """One loader per DP rank, each opening every source."""

    def __init__(self, source_paths: dict[str, str], dp_ranks: int,
                 workers: int, seq_len: int, rows: int,
                 schedule: MixSchedule, vocab_size: int = 50_000,
                 seed: int = 0):
        self.schedule = schedule
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.rows = rows
        self.rngs = [np.random.default_rng(seed + r)
                     for r in range(dp_ranks)]
        self.loaders = []
        for r in range(dp_ranks):
            readers = {n: SourceReader(p) for n, p in source_paths.items()}
            self.loaders.append(ColocatedRankLoader(
                r, readers, workers, seq_len, rows))

    def memory_bytes(self) -> int:
        return sum(l.memory_bytes() for l in self.loaders)

    def rank_batch(self, rank: int, step: int,
                   samples_per_rank: int) -> packing.PackedBatch:
        """Independent per-rank sampling (no global orchestration)."""
        l = self.loaders[rank]
        counts = sample_counts(self.schedule.weights(step),
                               samples_per_rank, self.rngs[rank])
        samples = []
        for src, k in counts.items():
            if src not in l.readers or k == 0:
                continue
            for rec in l.readers[src].read(k):
                samples.append(transform_record(rec, src, self.vocab_size))
        return packing.pack_sequences(samples, self.seq_len, self.rows)

    def close(self):
        for l in self.loaders:
            for r in l.readers.values():
                r.close()
