"""Declarative orchestration primitives (paper §4.2) and LoadingPlan.

An ``Orchestration`` is created by the Planner per step from (a) buffer
metadata collected from Source Loaders and (b) the ClientPlaceTree.  The
user strategy calls the primitives — mix / distribute / cost / balance /
broadcast_at — then ``plan()`` emits the LoadingPlan that Source Loaders
and Data Constructors execute.  Fig. 9's two use cases are provided as
ready-made strategies in strategies.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.balance import balance_items, bin_loads, imbalance
from repro.core.dgraph import DGraph, SELECTED
from repro.core.mixing import MixSchedule, sample_counts
from repro.core.placetree import ClientPlaceTree


@dataclasses.dataclass
class PlanEntry:
    sample_id: str
    source: str
    bucket: int            # distribute() bucket (DP consumer index)
    bin: int               # microbatch index within the bucket


@dataclasses.dataclass
class LoadingPlan:
    step: int
    buckets: int
    bins: int
    entries: list                      # list[PlanEntry]
    distribute_axis: str = "DP"
    broadcast_axes: tuple = ()
    diagnostics: dict = dataclasses.field(default_factory=dict)

    def per_source(self) -> dict[str, list[PlanEntry]]:
        out: dict[str, list[PlanEntry]] = {}
        for e in self.entries:
            out.setdefault(e.source, []).append(e)
        return out

    def per_bucket(self) -> dict[int, list[PlanEntry]]:
        out: dict[int, list[PlanEntry]] = {}
        for e in self.entries:
            out.setdefault(e.bucket, []).append(e)
        return out


class Orchestration:
    def __init__(self, buffer_meta: Sequence[dict], tree: ClientPlaceTree,
                 step: int, seed: int = 0):
        self.buffer_meta = list(buffer_meta)
        self.tree = tree
        self.step = step
        self.rng = np.random.default_rng(hash((seed, step)) % 2**32)
        self._selected: list[dict] = []
        self._graphs: dict[str, DGraph] = {}
        self._n_buckets: Optional[int] = None
        self._distribute_axis = "DP"
        self._n_bins = 1
        self._costfn: Optional[Callable] = None
        self._broadcast: tuple = ()
        self._diag: dict = {}

    # ---------------------------------------------------------- mix()
    def mix(self, schedule: MixSchedule, total_samples: int) -> list[dict]:
        """Probabilistic source selection for this step.  Only sampled data
        participates in subsequent orchestration."""
        weights = schedule.weights(self.step)
        by_source: dict[str, list[dict]] = {}
        for m in self.buffer_meta:
            by_source.setdefault(m["source"], []).append(m)
        counts = sample_counts(
            {s: w for s, w in weights.items() if s in by_source},
            total_samples, self.rng)
        picked = []
        for src, k in counts.items():
            avail = by_source.get(src, [])
            picked.extend(avail[:k])   # FIFO from the loader buffer
        self._selected = picked
        self._diag["mix_weights"] = weights
        self._diag["mix_counts"] = {s: len([m for m in picked
                                            if m["source"] == s])
                                    for s in counts}
        return picked

    # -------------------------------------------------------- dgraph()
    def dgraph(self, name: str = "main",
               select: Optional[Callable[[dict], bool]] = None) -> DGraph:
        base = self._selected if self._selected else self.buffer_meta
        g = DGraph.from_buffer(base, name, select)
        g.mark(g.nodes, SELECTED, "mix")
        self._graphs[name] = g
        return g

    # ---------------------------------------------------- distribute()
    def distribute(self, axis: str = "DP",
                   group_size: Optional[int] = None) -> int:
        self._distribute_axis = axis
        self._n_buckets = self.tree.buckets(axis, group_size)
        return self._n_buckets

    def microbatches(self, n_bins: int) -> int:
        self._n_bins = max(int(n_bins), 1)
        return self._n_bins

    # ---------------------------------------------------------- cost()
    def cost(self, costfn: Callable[[dict], float],
             graph: Optional[DGraph] = None):
        self._costfn = costfn
        for g in ([graph] if graph else self._graphs.values()):
            g.with_cost(costfn)

    # ------------------------------------------------------- balance()
    def balance(self, method: str = "greedy_binpack",
                level: str = "inter", graph: Optional[DGraph] = None,
                keep_buckets: bool = False) -> dict:
        """level:
          'inter'       — balance samples across buckets, then greedy
                          bins inside each bucket (inter-microbatch);
          'intra'       — buckets already fixed; only rebalance bins;
          'interleaved' — balance across all bucket*bin slots at once.
        ``keep_buckets=True`` preserves an earlier bucket assignment
        (used when combining encoder + backbone strategies)."""
        if self._n_buckets is None:
            raise RuntimeError("call distribute() before balance()")
        g = graph or self._graphs.get("main") \
            or next(iter(self._graphs.values()))
        costs = g.costs()
        nb, m = self._n_buckets, self._n_bins

        if level == "interleaved":
            assign = balance_items(costs, nb * m, method)
            g.assign_buckets([a // m for a in assign])
            g.assign_bins(g.nodes, [a % m for a in assign])
        else:
            if level == "inter" and not keep_buckets:
                g.assign_buckets(balance_items(costs, nb, method))
            elif g.nodes and g.nodes[0].bucket is None:
                # intra with no prior assignment: round-robin buckets
                g.assign_buckets([i % nb for i in range(len(g.nodes))])
            for b, nodes in g.by_bucket().items():
                sub = balance_items([n.cost for n in nodes], m, method)
                g.assign_bins(nodes, sub)

        loads = bin_loads(costs, [n.bucket for n in g.nodes], nb)
        diag = {"bucket_loads": loads, "imbalance": imbalance(loads),
                "method": method, "level": level}
        self._diag[f"balance:{g.name}"] = diag
        return diag

    # -------------------------------------------------- broadcast_at()
    def broadcast_at(self, *axes: str):
        self._broadcast = tuple(axes)
        self.tree.set_broadcast(axes)

    # ---------------------------------------------------------- plan()
    def plan(self, graph: Optional[DGraph] = None) -> LoadingPlan:
        g = graph or self._graphs.get("main") \
            or next(iter(self._graphs.values()))
        entries = [PlanEntry(n.sample_id, n.source,
                             int(n.bucket or 0), int(n.bin or 0))
                   for n in g.nodes]
        return LoadingPlan(
            step=self.step, buckets=self._n_buckets or 1,
            bins=self._n_bins, entries=entries,
            distribute_axis=self._distribute_axis,
            broadcast_axes=self._broadcast, diagnostics=dict(self._diag))


# --------------------------------------------------------------- low-level
def plan_raw(buffer_meta, tree, step, assign_fn) -> LoadingPlan:
    """Escape hatch (paper: plan_raw/loader_do_plan/constructor_do_plan):
    ``assign_fn(meta) -> (bucket, bin)`` programs the plan directly."""
    entries = []
    nb = mb = 1
    for m in buffer_meta:
        b, mbin = assign_fn(m)
        nb = max(nb, b + 1)
        mb = max(mb, mbin + 1)
        entries.append(PlanEntry(m["sample_id"], m["source"], b, mbin))
    return LoadingPlan(step=step, buckets=nb, bins=mb, entries=entries)
