"""MultiSource Loader AutoScaling (§5).

Phase 1 (offline) — ``auto_partition``: cluster sources by transformation
cost, derive worker counts per resource level, and split heavy sources
into data-parallel loader actors, under per-source / per-actor worker
bounds and a memory budget.

Phase 2 (online) — ``MixtureScaler``: reacts to the Planner's
mixture-shift triggers by adding/removing data-parallel shards of a
source's loaders, resharding live (new actors join the next plan).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

from repro.core.actors import ActorRuntime
from repro.core.source_loader import SourceLoader


@dataclasses.dataclass(frozen=True)
class SourceProfile:
    name: str
    transform_cost: float      # mean per-sample cost (P_k)
    memory_bytes: int          # access-state footprint (M_k)
    weight: float = 1.0        # current mixture weight


@dataclasses.dataclass
class LoaderConfig:
    source: str
    shard_index: int
    shard_count: int
    workers: int

    @property
    def actor_name(self) -> str:
        return f"loader:{self.source}:{self.shard_index}of{self.shard_count}"


@dataclasses.dataclass(frozen=True)
class PartitionLimits:
    cluster_size: int = 4          # paper: 4 optimal in most scenarios
    w_src: int = 16                # per-source worker bound
    w_actor: int = 4               # per-actor worker bound
    total_workers: int = 64        # resource pool (minus constructor/planner)
    memory_budget: int = 1 << 34   # bytes


def auto_partition(profiles: list[SourceProfile],
                   limits: PartitionLimits = PartitionLimits()) -> \
        list[LoaderConfig]:
    """Three stages (paper §5.1): source clustering -> resource levels ->
    configuration generation."""
    if not profiles:
        return []
    # (1) cluster by descending transformation cost
    ordered = sorted(profiles, key=lambda p: -p.transform_cost)
    G = max(1, math.ceil(len(ordered) / limits.cluster_size))
    clusters = [ordered[i::G] for i in range(G)]
    clusters = [c for c in clusters if c]
    cluster_cost = [sum(p.transform_cost * max(p.weight, 1e-6) for p in c)
                    / len(c) for c in clusters]

    # (2) resource levels: workers proportional to relative cluster cost
    floor = min(cluster_cost)
    ratios = [c / max(floor, 1e-9) for c in cluster_cost]
    raw = [max(1.0, r) for r in ratios]
    scale = limits.total_workers / max(sum(
        raw[i] * len(clusters[i]) for i in range(len(clusters))), 1e-9)
    per_source_workers = [
        max(1, min(limits.w_src, int(raw[i] * scale)))
        for i in range(len(clusters))]

    # (3) configuration generation: split workers into actors of w_actor
    out: list[LoaderConfig] = []
    for ci, cluster in enumerate(clusters):
        w = per_source_workers[ci]
        n_actors = max(1, math.ceil(w / limits.w_actor))
        for p in cluster:
            # memory constraint: enough shards that per-actor access state
            # fits the budget share
            mem_shards = max(1, math.ceil(
                p.memory_bytes * n_actors / max(limits.memory_budget, 1)))
            shards = max(n_actors, mem_shards)
            wk = max(1, min(limits.w_actor, w // shards or 1))
            for i in range(shards):
                out.append(LoaderConfig(p.name, i, shards, wk))
    return out


class MixtureScaler:
    """Online phase: add/remove shards when the Planner fires triggers."""

    def __init__(self, runtime: ActorRuntime, paths: dict[str, str],
                 register: Callable, unregister: Callable,
                 max_shards: int = 8, workers: int = 2,
                 loader_factory: Optional[Callable] = None):
        self.runtime = runtime
        self.paths = paths
        self.register = register        # (name, handle) -> join planning
        self.unregister = unregister    # (name) -> leave planning
        # LoaderConfig -> SourceLoader; lets the Overlord hand resharded
        # loaders the shared retry/breaker/dlq/telemetry wiring instead
        # of spawning bare ones
        self.loader_factory = loader_factory
        self.max_shards = max_shards
        self.workers = workers
        self.shards: dict[str, int] = {}
        self.events: list[dict] = []

    def current_shards(self, source: str) -> int:
        return self.shards.get(source, 1)

    def on_trigger(self, source: str, direction: str):
        cur = self.current_shards(source)
        if direction == "up" and cur < self.max_shards:
            new = cur + 1
        elif direction == "down" and cur > 1:
            new = cur - 1
        else:
            return
        self.reshard(source, new)

    def reshard(self, source: str, shards: int):
        """Live reshard: spawn the new shard set, register, then retire the
        old actors (next plan uses the new buffers — no delivery gap)."""
        old = [n for n in self.runtime.actors()
               if n.startswith(f"loader:{source}:") and "::shadow" not in n]
        new_handles = {}
        for i in range(shards):
            cfg = LoaderConfig(source, i, shards, self.workers)
            if self.loader_factory is not None:
                loader = self.loader_factory(cfg)
            else:
                loader = SourceLoader(source, self.paths[source],
                                      (i, shards), cfg.workers)
            h = self.runtime.spawn(cfg.actor_name, loader)
            new_handles[cfg.actor_name] = h
        for name, h in new_handles.items():
            self.register(name, h)
        for name in old:
            if name not in new_handles:
                self.unregister(name)
                h = self.runtime.get(name)
                if h.alive:
                    h.stop()
        self.shards[source] = shards
        self.events.append({"source": source, "shards": shards})
