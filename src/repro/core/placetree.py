"""ClientPlaceTree: logical tree model of the trainer device mesh (§4.1).

The tree has one level per parallelism axis, ordered outermost->innermost
(e.g. PP -> DP -> CP -> TP).  Leaves are trainer clients (global ranks).
It answers the two questions the data plane needs:

  * distribute(axis): how many independent data consumers exist at an axis
    (and which clients sit under each), including ``group_size`` coarsening
    for super-large clusters;
  * client_view(rank): which *view* of the batch a given client receives —
    the parallelism transformation (full data / CP slice / metadata-only /
    suppressed-by-broadcast), per paper §2.1 + Fig. 6.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ClientView:
    """What one trainer client receives for a step."""
    rank: int
    coords: dict                  # axis -> index
    role: str                     # "data" | "metadata" | "none"
    cp_rank: int = 0
    cp_degree: int = 1
    dp_index: int = 0             # which DP bucket this client consumes


class ClientPlaceTree:
    def __init__(self, axes: Sequence[tuple[str, int]]):
        """axes: ordered (name, size), outermost first.
        Example: [("PP", 4), ("DP", 8), ("CP", 2), ("TP", 4)]."""
        if not axes:
            raise ValueError("need at least one axis")
        self.axes = list(axes)
        self.names = [a for a, _ in axes]
        self.sizes = {a: s for a, s in axes}
        self.world = math.prod(s for _, s in axes)
        self._broadcast_axes: set[str] = set()

    # -- coordinates ------------------------------------------------------
    def coords(self, rank: int) -> dict:
        out = {}
        rem = rank
        for name, size in reversed(self.axes):
            out[name] = rem % size
            rem //= size
        return out

    def rank_of(self, coords: dict) -> int:
        r = 0
        for name, size in self.axes:
            r = r * size + coords.get(name, 0)
        return r

    def nodes_at(self, axis: str) -> int:
        """Number of buckets when distributing along ``axis``: the product
        of sizes from the root down to and including ``axis``, ignoring
        axes that merely replicate data (TP) below it."""
        if axis == "WORLD":
            return self.world
        if axis not in self.sizes:
            raise KeyError(f"unknown axis {axis!r}; have {self.names}")
        n = 1
        for name, size in self.axes:
            n *= size
            if name == axis:
                return n
        return n

    def buckets(self, axis: str, group_size: Optional[int] = None) -> int:
        n = self.nodes_at(axis)
        if group_size:
            return math.ceil(n / group_size)
        return n

    def clients_under(self, axis: str, bucket: int) -> list[int]:
        """Global ranks beneath one bucket at ``axis``."""
        if axis == "WORLD":
            return [bucket]
        per = self.world // self.nodes_at(axis)
        return list(range(bucket * per, (bucket + 1) * per))

    # -- parallelism transformation ----------------------------------------
    def set_broadcast(self, axes: Sequence[str]):
        """broadcast_at(): trainer broadcasts along these axes; only the
        0-index client of each fetches (paper §4.2/§6.2)."""
        for a in axes:
            if a != "WORLD" and a not in self.sizes:
                raise KeyError(f"unknown axis {a!r}")
        self._broadcast_axes = set(axes)

    def client_view(self, rank: int, distribute_axis: str = "DP") -> \
            ClientView:
        c = self.coords(rank)
        # broadcast suppression: only the 0th member along broadcast axes
        # fetches data
        for a in self._broadcast_axes:
            if c.get(a, 0) != 0:
                return ClientView(rank, c, role="none")
        # pipeline: stages > 0 get metadata only
        if "PP" in self.sizes and c.get("PP", 0) != 0:
            return ClientView(rank, c, role="metadata")
        cp = self.sizes.get("CP", 1)
        # which DP bucket: index of this client's bucket at distribute axis
        per = self.world // self.nodes_at(distribute_axis) \
            if distribute_axis != "WORLD" else 1
        dp_index = rank // per if per else rank
        return ClientView(rank, c, role="data", cp_rank=c.get("CP", 0),
                          cp_degree=cp, dp_index=dp_index)

    # -- summary ------------------------------------------------------------
    def data_fetching_clients(self, distribute_axis: str = "DP") -> list:
        return [r for r in range(self.world)
                if self.client_view(r, distribute_axis).role == "data"]

    def describe(self) -> str:
        parts = [f"{a}={s}" for a, s in self.axes]
        return f"ClientPlaceTree({' x '.join(parts)}, world={self.world}, " \
               f"broadcast={sorted(self._broadcast_axes)})"

    @classmethod
    def from_mesh(cls, mesh, pp: int = 1, cp: int = 1):
        """Build from a jax Mesh: ('pod','data') -> DP, 'model' -> TP, with
        explicit PP/CP factors carved out of DP if requested."""
        import math as _m
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
        tp = mesh.shape.get("model", 1)
        assert dp % (pp * cp) == 0, (dp, pp, cp)
        dp //= (pp * cp)
        return cls([("PP", pp), ("DP", dp), ("CP", cp), ("TP", tp)])
