"""Token-choice top-k MoE with ROW-LOCAL capacity dispatch.

Design notes (TPU adaptation + §Perf iteration log in EXPERIMENTS.md):
  * No GShard one-hot dispatch einsums — they cost g*E*C*d MAC flops and
    poison the HLO-FLOPs roofline.
  * Capacity is per BATCH ROW (C_row = ceil(cf * s * k / E)), so the
    scatter into the dispatch buffer indexes [row, expert, slot] and rows
    are batch-sharded: every scatter/gather is shard-LOCAL.  The only
    cross-chip movement is an explicit sharding transpose of the buffer
    (batch-sharded -> expert-sharded), which GSPMD lowers to the inherent
    all-to-all of expert parallelism.  The original global-capacity
    formulation made GSPMD all-reduce a (E, C_global, d) buffer across the
    data axis: 12.9 TB/device wire per step on qwen3-moe-30b (measured,
    see EXPERIMENTS.md §Perf iteration 1) vs ~0.5 TB for this layout.
  * Experts padded to a multiple of EXPERT_PAD so the expert dim shards
    16-way (granite's 40 -> 48); dead experts get no tokens.
  * Overflow tokens drop into slot C_row (zeroed before combine).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.params import EMBED, EXPERT, MLP, ParamDef
from repro.sharding.logical import shard

EXPERT_PAD = 16  # pad experts to a multiple of the tensor-axis size


def padded_experts(cfg) -> int:
    return int(math.ceil(cfg.num_experts / EXPERT_PAD) * EXPERT_PAD)


def moe_def(cfg) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    ep = padded_experts(cfg)
    return {
        "router": ParamDef((d, cfg.num_experts), (EMBED, None),
                           init="scaled", dtype=jnp.float32),
        "w_gate": ParamDef((ep, d, dff), (EXPERT, EMBED, MLP), init="scaled"),
        "w_up": ParamDef((ep, d, dff), (EXPERT, EMBED, MLP), init="scaled"),
        "w_down": ParamDef((ep, dff, d), (EXPERT, MLP, EMBED), init="scaled"),
    }


def row_capacity(cfg, seq_len: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * seq_len
                      * cfg.experts_per_token / cfg.num_experts))
    return max(c, 1)


# ------------------------------------------------------- local dispatch
def _dispatch_local(x, ids, dest, ep: int, C: int):
    """Pure-local SINGLE scatter into the dispatch buffer.
    x: (b, s, d); ids: (b, s, k); dest: (b, s*k) -> (b, ep, C+1, d).
    One scatter over all (token, choice) pairs: a k-iteration scatter loop
    reads+writes the full buffer k times (measured 43 GB/layer/device on
    qwen3-moe, §Perf iteration 4)."""
    b, s, d = x.shape
    k = ids.shape[2]
    rows = jnp.arange(b)[:, None]
    idf = ids.reshape(b, s * k)                       # token-major (s, k)
    x_rep = jnp.broadcast_to(x[:, :, None, :], (b, s, k, d)) \
        .reshape(b, s * k, d)
    buf = jnp.zeros((b, ep, C + 1, d), x.dtype)
    return buf.at[rows, idf, dest].add(x_rep, mode="drop")


def _combine_local(out_buf, ids, dest, weights):
    """Pure-local single gather + weighted combine.
    out_buf: (b, ep, C+1, d) -> (b, s, d) fp32."""
    b, s, k = ids.shape
    d = out_buf.shape[-1]
    rows = jnp.arange(b)[:, None]
    idf = ids.reshape(b, s * k)
    gathered = out_buf[rows, idf, dest].reshape(b, s, k, d)
    return jnp.sum(weights[..., None]
                   * gathered.astype(jnp.float32), axis=2)


def _shmap_batch(fn, args, extra, out_rank4: bool):
    """Run ``fn`` per batch shard via shard_map when a mesh is active
    (indices are row-local, so the body needs no collectives); plain call
    otherwise (tests on one device)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.logical import current_rules
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return fn(*args, *extra)
    mesh = rules.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = args[0].shape[0]
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    if not batch_axes or bsz % n != 0:
        return fn(*args, *extra)
    in_specs = tuple(P(batch_axes, *([None] * (a.ndim - 1)))
                     for a in args)
    out_specs = P(batch_axes, None, None, None) if out_rank4 \
        else P(batch_axes, None, None)
    body = lambda *xs: fn(*xs, *extra)
    return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(*args)


def moe_block(p: dict, cfg, x: jax.Array, *, global_tokens: int = 0,
              router_aux: bool = True):
    """x: (b, s, d) -> (out (b, s, d), aux_loss scalar fp32)."""
    b, s, d = x.shape
    k = cfg.experts_per_token
    E = cfg.num_experts
    ep = padded_experts(cfg)
    C = row_capacity(cfg, s)

    logits = x.astype(jnp.float32) @ p["router"]              # (b, s, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                    # (b, s, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, -1, keepdims=True), 1e-9)            # renormalize

    # slot position of each (token, choice) within (row, expert)
    idf = ids.reshape(b, s * k)                               # (b, g)
    oh = jax.nn.one_hot(idf, E, dtype=jnp.int32)              # (b, g, E)
    pos_all = jnp.cumsum(oh, axis=1) - oh
    pos = jnp.take_along_axis(pos_all, idf[..., None], axis=2)[..., 0]
    dest = jnp.minimum(pos, C)                                # C = overflow

    # row-local scatter.  GSPMD lowers a scatter over a sharded batch dim
    # conservatively (full-buffer all-reduce PER scatter — measured 12.9
    # TB/step on qwen3-moe; EXPERIMENTS.md §Perf it.2), so dispatch and
    # combine run INSIDE shard_map: indices are row-local by construction,
    # making both pure local memory ops.  The only collective left is the
    # buffer's sharding transpose (batch-sharded -> expert-sharded), the
    # inherent all-to-all of expert parallelism.
    buf = _shmap_batch(_dispatch_local, (x, ids, dest),
                       extra=(ep, C), out_rank4=True)
    # (no intermediate batch-sharded constraint here: shard_map's
    # out_specs already pin the layout, and an extra constraint makes
    # GSPMD materialize it separately in fwd AND remat-bwd — measured
    # +775 GB of collective-permute traffic, §Perf iteration 3)

    # sharding transpose + batched expert SwiGLU
    buf_e = shard(buf, None, "act_expert", "cap", None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf_e, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf_e, p["w_up"])
    h = shard(h, None, "act_expert", "cap", "act_mlp")
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_e = out_e.at[:, :, C].set(0.0)                        # drop overflow
    # transpose back and combine (row-local gathers)
    out_buf = shard(out_e, "batch", None, "cap", None)
    out = _shmap_batch(_combine_local, (out_buf, ids, dest, weights),
                       extra=(), out_rank4=False)

    aux = jnp.float32(0.0)
    if router_aux:
        # standard load-balancing loss: E * sum_e f_e * p_e
        me = jnp.mean(probs, axis=(0, 1))                     # (E,)
        fe = jnp.mean(jnp.sum(
            jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=2),
            axis=(0, 1)) / k                                  # (E,)
        aux = E * jnp.sum(me * fe)

    return out.astype(x.dtype), aux
