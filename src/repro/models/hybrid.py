"""Zamba2-style hybrid: Mamba2 backbone + a SHARED transformer block applied
every ``attn_every`` layers (weight sharing is the zamba2 signature).

Structure (81 layers, attn_every=6): 13 super-blocks of [6 x mamba2 +
shared-attn application] + 3 tail mamba2 layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.attention import decode_attention, expand_kv, \
    segment_attention
from repro.models.params import EMBED, VOCAB, ParamDef, stacked
from repro.sharding.logical import shard


def _split_counts(cfg: ModelConfig) -> tuple[int, int]:
    n_blocks = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_blocks * cfg.attn_every
    return n_blocks, tail


def _shared_attn_def(cfg) -> dict:
    return {
        "attn_norm": L.rmsnorm_def(cfg.d_model),
        "attn": L.attention_proj_def(cfg),
        "mlp_norm": L.rmsnorm_def(cfg.d_model),
        "mlp": L.swiglu_def(cfg.d_model, cfg.d_ff),
    }


def hybrid_defs(cfg: ModelConfig) -> dict:
    n_blocks, tail = _split_counts(cfg)
    mamba = {"norm": L.rmsnorm_def(cfg.d_model), "mixer": ssm.mamba2_def(cfg)}
    defs = {
        "embed": L.embedding_def(cfg.vocab_size, cfg.d_model),
        "blocks": stacked(stacked(mamba, cfg.attn_every), n_blocks),
        "shared_attn": _shared_attn_def(cfg),   # ONE copy, reused
        "final_norm": L.rmsnorm_def(cfg.d_model),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), (EMBED, VOCAB),
                            init="scaled"),
    }
    if tail:
        defs["tail"] = stacked(mamba, tail)
    return defs


def _mamba_layer(lp, cfg, h, seg):
    x = L.rmsnorm(lp["norm"], h, cfg.norm_eps)
    return h + ssm.mamba2_train(lp["mixer"], cfg, x, seg)


def _shared_attn_apply(sp, cfg, h, seg, pos):
    x = L.rmsnorm(sp["attn_norm"], h, cfg.norm_eps)
    q, k, v = L.qkv_project(sp["attn"], cfg, x, pos)
    k = expand_kv(k, cfg.num_heads)
    v = expand_kv(v, cfg.num_heads)
    attn = segment_attention(q, k, v, seg, seg, causal=True,
                             chunk=cfg.attn_chunk)
    h = h + L.attn_out_project(sp["attn"], attn)
    x = L.rmsnorm(sp["mlp_norm"], h, cfg.norm_eps)
    return h + L.swiglu(sp["mlp"], x)


def forward(params, cfg: ModelConfig, batch):
    seg, pos = batch["segment_ids"], batch["positions"]
    h = L.embed(params["embed"], batch["tokens"])
    h = shard(h, "batch", "seq", "act_embed")
    sp = params["shared_attn"]

    def inner(h, lp):
        return _mamba_layer(lp, cfg, h, seg), None

    def block_fn(h, bp):
        h, _ = jax.lax.scan(inner, h, bp)
        h = _shared_attn_apply(sp, cfg, h, seg, pos)
        h = shard(h, "batch", "seq", "act_embed")
        return h, None

    body = jax.checkpoint(block_fn) if cfg.remat != "none" else block_fn
    h, _ = jax.lax.scan(body, h, params["blocks"])
    if "tail" in params:
        h, _ = jax.lax.scan(inner, h, params["tail"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["unembed"]
    return shard(logits, "batch", "seq", "act_vocab"), jnp.float32(0.0)


def prefill(params, cfg: ModelConfig, batch):
    """Prompt pass: returns (last-token logits, cache) for decode."""
    seg, pos = batch["segment_ids"], batch["positions"]
    h = L.embed(params["embed"], batch["tokens"])
    h = shard(h, "batch", "seq", "act_embed")
    sp = params["shared_attn"]
    n_blocks, tail = _split_counts(cfg)

    def inner(h, lp):
        x = L.rmsnorm(lp["norm"], h, cfg.norm_eps)
        y, st = ssm.mamba2_train(lp["mixer"], cfg, x, seg, return_state=True)
        return h + y, st

    def block_fn(h, bp):
        h, states = jax.lax.scan(inner, h, bp)
        x = L.rmsnorm(sp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(sp["attn"], cfg, x, pos)
        ke = expand_kv(k, cfg.num_heads)
        ve = expand_kv(v, cfg.num_heads)
        attn = segment_attention(q, ke, ve, seg, seg, causal=True,
                                 chunk=cfg.attn_chunk)
        h = h + L.attn_out_project(sp["attn"], attn)
        x = L.rmsnorm(sp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.swiglu(sp["mlp"], x)
        return h, (states, {"k": k, "v": v})

    h, (block_states, kv) = jax.lax.scan(block_fn, h, params["blocks"])
    cache = {
        "blocks": jax.tree.map(
            lambda a: a.reshape((n_blocks * cfg.attn_every,) + a.shape[2:]),
            block_states),
        "k": kv["k"].astype(jnp.bfloat16),
        "v": kv["v"].astype(jnp.bfloat16),
    }
    if "tail" in params:
        h, tail_states = jax.lax.scan(inner, h, params["tail"])
        cache["tail"] = tail_states
    else:
        cache["tail"] = jax.tree.map(
            lambda a: a[:0], cache["blocks"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = h[:, -1:, :] @ params["unembed"]
    return logits, cache


# ---------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    n_blocks, tail = _split_counts(cfg)
    hd = cfg.resolved_head_dim()
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    kv_shape = (n_blocks, batch, max_len, cfg.num_kv_heads, hd)
    mk = lambda n: {
        "ssm": jnp.zeros((n, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((n, batch, ssm.CONV_K - 1, d_in), jnp.float32),
    }
    return {
        "blocks": mk(n_blocks * cfg.attn_every),
        "tail": mk(tail),
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
    }


def cache_logical_axes(cfg: ModelConfig):
    st = {"ssm": ("layers", "batch", "act_ssm", None, None),
          "conv": ("layers", "batch", None, "act_ssm")}
    return {"blocks": st, "tail": dict(st),
            "k": ("layers", "batch", "kv_seq", "act_kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "act_kv_heads", None)}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    b = tokens.shape[0]
    n_blocks, tail = _split_counts(cfg)
    h = L.embed(params["embed"], tokens)
    positions = jnp.full((b, 1), pos, jnp.int32)
    cache_len = jnp.full((b,), pos + 1, jnp.int32)
    sp = params["shared_attn"]

    def mamba_step(h, xs):
        lp, st = xs
        x = L.rmsnorm(lp["norm"], h, cfg.norm_eps)
        y, st_new = ssm.mamba2_decode(lp["mixer"], cfg, x, st)
        return h + y, st_new

    # reshape the flat per-layer mamba states into (n_blocks, attn_every)
    bs = jax.tree.map(
        lambda a: a.reshape((n_blocks, cfg.attn_every) + a.shape[1:]),
        cache["blocks"])

    def block_fn(h, xs):
        bp, st, ck, cv = xs
        h, st_new = jax.lax.scan(mamba_step, h, (bp, st))
        x = L.rmsnorm(sp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(sp["attn"], cfg, x, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 pos, axis=1)
        attn = decode_attention(q, ck, cv, cache_len)
        h = h + L.attn_out_project(sp["attn"], attn)
        x = L.rmsnorm(sp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.swiglu(sp["mlp"], x)
        return h, (st_new, ck, cv)

    h, (bs_new, ck_new, cv_new) = jax.lax.scan(
        block_fn, h, (params["blocks"], bs, cache["k"], cache["v"]))
    new_cache = {
        "blocks": jax.tree.map(
            lambda a: a.reshape((n_blocks * cfg.attn_every,) + a.shape[2:]),
            bs_new),
        "k": ck_new, "v": cv_new,
        "tail": cache["tail"],
    }
    if "tail" in params:
        h, tail_new = jax.lax.scan(mamba_step, h,
                                   (params["tail"], cache["tail"]))
        new_cache["tail"] = tail_new
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["unembed"]
    return logits, new_cache
