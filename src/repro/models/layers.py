"""Shared building blocks: norms, RoPE, SwiGLU MLP, embeddings, GQA attention
projections.  Pure functions over params dicts declared with ParamDef.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import (
    EMBED, HEADS, HEAD_DIM, KV_HEADS, MLP, VOCAB, ParamDef,
)
from repro.sharding.logical import shard


# --------------------------------------------------------------------- norm
def rmsnorm_def(dim: int) -> dict:
    return {"scale": ParamDef((dim,), (None,), init="ones", dtype=jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(dt)


def layernorm_def(dim: int) -> dict:
    return {
        "scale": ParamDef((dim,), (None,), init="ones", dtype=jnp.float32),
        "bias": ParamDef((dim,), (None,), init="zeros", dtype=jnp.float32),
    }


def layernorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(dt)


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- mlp
def swiglu_def(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamDef((d_model, d_ff), (EMBED, MLP), init="scaled"),
        "w_up": ParamDef((d_model, d_ff), (EMBED, MLP), init="scaled"),
        "w_down": ParamDef((d_ff, d_model), (MLP, EMBED), init="scaled"),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", "seq", "act_mlp")
    return h @ p["w_down"]


def gelu_mlp_def(d_model: int, d_ff: int) -> dict:
    return {
        "w_up": ParamDef((d_model, d_ff), (EMBED, MLP), init="scaled"),
        "b_up": ParamDef((d_ff,), (MLP,), init="zeros"),
        "w_down": ParamDef((d_ff, d_model), (MLP, EMBED), init="scaled"),
        "b_down": ParamDef((d_model,), (None,), init="zeros"),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    h = shard(h, "batch", "seq", "act_mlp")
    return h @ p["w_down"] + p["b_down"]


# --------------------------------------------------------------- embeddings
def embedding_def(vocab: int, d_model: int) -> dict:
    return {"table": ParamDef((vocab, d_model), (VOCAB, EMBED), scale=1.0)}


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    logits = x @ p["table"].T
    return shard(logits, "batch", "seq", "act_vocab")


# --------------------------------------------------- attention projections
def attention_proj_def(cfg) -> dict:
    hd = cfg.resolved_head_dim()
    d = {
        "wq": ParamDef((cfg.d_model, cfg.num_heads, hd),
                       (EMBED, HEADS, HEAD_DIM), init="scaled"),
        "wk": ParamDef((cfg.d_model, cfg.num_kv_heads, hd),
                       (EMBED, KV_HEADS, HEAD_DIM), init="scaled"),
        "wv": ParamDef((cfg.d_model, cfg.num_kv_heads, hd),
                       (EMBED, KV_HEADS, HEAD_DIM), init="scaled"),
        "wo": ParamDef((cfg.num_heads, hd, cfg.d_model),
                       (HEADS, HEAD_DIM, EMBED), init="scaled"),
    }
    if cfg.qk_norm:
        d["q_norm"] = rmsnorm_def(hd)
        d["k_norm"] = rmsnorm_def(hd)
    return d


def qkv_project(p: dict, cfg, x: jax.Array,
                positions: Optional[jax.Array]) -> tuple:
    """x: (b, s, d) -> q (b,s,H,hd), k/v (b,s,KH,hd) with qk_norm + RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "act_heads", None)
    k = shard(k, "batch", "seq", "act_kv_heads", None)
    v = shard(v, "batch", "seq", "act_kv_heads", None)
    return q, k, v


def attn_out_project(p: dict, attn: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
