"""Mamba2 (SSD) block — chunked state-space duality formulation.

Used by the zamba2-7b hybrid.  Train path: chunked scan (O(s*L) memory with
rematerialized chunk bodies); decode path: single-step recurrence over the
carried (conv, ssm) state.

Recurrence (per head h, state size N, head dim P):
    S_t = a_t * S_{t-1} + (dt_t * x_t) (x) B_t          S in R^{P x N}
    y_t = C_t . S_t + D * x_t
with a_t = exp(dt_t * A), A = -exp(A_log) < 0, dt_t = softplus(...).

Simplifications vs the reference CUDA implementation (documented in
DESIGN.md): single B/C group; the causal depthwise conv is applied to the
SSM input stream only.  Both preserve the compute/memory character that the
dry-run and roofline analysis measure.

Packing semantics: the SSM state resets exactly at segment starts (tracked
as reset COUNTS, see the chunked scan); the depthwise conv window leaks up
to CONV_K-1 tokens across packed boundaries — same accepted leakage as
RWKV's token shift (tests/test_models.py pins this contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import (
    CONV, EMBED, ParamDef, SSM_INNER, SSM_STATE,
)
from repro.models.layers import rmsnorm_def, rmsnorm
from repro.sharding.logical import shard

CONV_K = 4  # depthwise conv kernel width


def mamba2_def(cfg) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n_heads = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    return {
        # fused input projection -> [z, x, B, C, dt]
        "w_z": ParamDef((d, d_in), (EMBED, SSM_INNER), init="scaled"),
        "w_x": ParamDef((d, d_in), (EMBED, SSM_INNER), init="scaled"),
        "w_B": ParamDef((d, N), (EMBED, SSM_STATE), init="scaled"),
        "w_C": ParamDef((d, N), (EMBED, SSM_STATE), init="scaled"),
        "w_dt": ParamDef((d, n_heads), (EMBED, None), init="scaled"),
        "dt_bias": ParamDef((n_heads,), (None,), init="zeros"),
        "A_log": ParamDef((n_heads,), (None,), init="zeros"),
        "D": ParamDef((n_heads,), (None,), init="ones"),
        "conv": ParamDef((CONV_K, d_in), (CONV, SSM_INNER), init="scaled"),
        "norm": rmsnorm_def(d_in),
        "w_out": ParamDef((d_in, d), (SSM_INNER, EMBED), init="scaled"),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (b, s, c); w: (K, c).  Causal: output t sees x[t-K+1 .. t]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def _project(p, cfg, x):
    """Shared projection for train/decode.  x: (b, s, d)."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    B = (x @ p["w_B"]).astype(jnp.float32)
    C = (x @ p["w_C"]).astype(jnp.float32)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    loga = dt * A                                  # (b, s, H) log decay
    return z, xs, B, C, dt, loga


def mamba2_train(p: dict, cfg, x: jax.Array, segment_ids: jax.Array,
                 return_state: bool = False):
    """x: (b, s, d_model); segment_ids: (b, s).  Returns (b, s, d_model),
    and with ``return_state`` also the final {ssm, conv} state (prefill)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    L = min(cfg.ssm_chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    prev_seg = jnp.pad(segment_ids[:, :-1], ((0, 0), (1, 0)))
    seg_reset = (segment_ids != prev_seg) | (segment_ids == 0)

    z, xs, Bv, Cv, dt, loga = _project(p, cfg, x)
    xs_raw = xs                                    # pre-conv stream (prefill)
    xs = _causal_depthwise_conv(xs, p["conv"])
    xs = shard(xs, "batch", "seq", "act_ssm")
    xh = xs.reshape(b, s, H, P).astype(jnp.float32)
    dtx = xh * dt[..., None]                       # (b, s, H, P)

    # chunked SSD scan.  Segment resets are tracked as COUNTS (never folded
    # into the fp32 decay cumsum — catastrophic cancellation; see rwkv.py).
    def split(a):  # (b, s, ...) -> (nc, b, L, ...)
        return a.reshape((b, nc, L) + a.shape[2:]).swapaxes(0, 1)

    xc, dc, Bc, Cc, lac = map(split, (dtx, dt, Bv, Cv, loga))
    rc = seg_reset.astype(jnp.int32).reshape(b, nc, L).swapaxes(0, 1)
    tri = jnp.tril(jnp.ones((L, L), bool))

    @jax.checkpoint
    def body(S, inp):
        xb, dtb, Bb, Cb, lab, rb = inp             # (b,L,H,P) (b,L,H) (b,L)
        cla = jnp.cumsum(lab, axis=1)              # (b, L, H) cumulative
        R = jnp.cumsum(rb, axis=1)                 # resets up to & incl t
        # intra-chunk: M[b,h,l,m] = (C_l . B_m) * exp(cla_l - cla_m),
        # valid iff l >= m and no reset in (m, l]  <=>  R_l == R_m
        scores = jnp.einsum("bln,bmn->blm", Cb, Bb)
        decay = jnp.exp(jnp.minimum(
            cla[:, :, None, :] - cla[:, None, :, :], 0.0))  # (b, l, m, H)
        valid = (R[:, :, None] == R[:, None, :]) \
            & tri[None, :, :]                      # (b, l, m)
        M = scores[..., None] * decay * valid[..., None]
        y = jnp.einsum("blmh,bmhp->blhp", M, xb)
        # inter-chunk: carried state survives only until the first reset
        carry_gate = (R == 0)[:, :, None]          # (b, l, 1)
        y = y + jnp.einsum("bln,bhpn,blh->blhp", Cb, S,
                           jnp.exp(cla) * carry_gate)
        # state update: kv_m survives iff no reset in (m, L]
        k_gate = (R[:, -1:] == R)[:, :, None]      # (b, m, 1)
        S_new = jnp.einsum("bmhp,bmn,bmh->bhpn", xb, Bb,
                           jnp.exp(cla[:, -1:, :] - cla) * k_gate) \
            + S * (jnp.exp(cla[:, -1])
                   * (R[:, -1] == 0)[:, None])[:, :, None, None]
        return S_new, y

    S0 = jnp.zeros((b, H, P, N), jnp.float32)
    S_final, ys = jax.lax.scan(body, S0, (xc, dc, Bc, Cc, lac, rc))
    y = ys.swapaxes(0, 1).reshape(b, s, H, P)
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)),
                cfg.norm_eps)
    y = shard(y.astype(x.dtype), "batch", "seq", "act_ssm")
    out = y @ p["w_out"]
    if return_state:
        state = {"ssm": S_final,
                 "conv": xs_raw[:, -(CONV_K - 1):].astype(jnp.float32)}
        return out, state
    return out


def mamba2_init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, CONV_K - 1, d_in), dtype),
    }


def mamba2_decode(p: dict, cfg, x: jax.Array, state: dict):
    """Single-step decode.  x: (b, 1, d_model).  Returns (y, new_state)."""
    b = x.shape[0]
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim

    z, xs, Bv, Cv, dt, loga = _project(p, cfg, x)
    # conv over carried window
    window = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
    w = p["conv"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
    xs1 = jax.nn.silu(conv_out)[:, None, :]
    xh = xs1.reshape(b, 1, H, P).astype(jnp.float32)

    a = jnp.exp(loga[:, 0])                        # (b, H)
    dtx = (xh * dt[..., None])[:, 0]               # (b, H, P)
    S = state["ssm"] * a[:, :, None, None] \
        + jnp.einsum("bhp,bn->bhpn", dtx, Bv[:, 0])
    y = jnp.einsum("bn,bhpn->bhp", Cv[:, 0], S)
    y = y + xh[:, 0] * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)),
                cfg.norm_eps).astype(x.dtype)
    new_state = {"ssm": S, "conv": window[:, 1:]}
    return y @ p["w_out"], new_state
