"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: the batch carries
precomputed frame embeddings ``enc_embeds`` (b, frames, d_model).  The
encoder is bidirectional; the decoder is causal with cross-attention.
Whisper uses LayerNorm; we keep that.  Learned absolute positions are
replaced by RoPE (TPU-friendly; noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import (
    decode_attention, expand_kv, segment_attention,
)
from repro.models.params import EMBED, VOCAB, ParamDef, stacked
from repro.sharding.logical import shard


def _enc_layer_def(cfg) -> dict:
    return {
        "attn_norm": L.layernorm_def(cfg.d_model),
        "attn": L.attention_proj_def(cfg),
        "mlp_norm": L.layernorm_def(cfg.d_model),
        "mlp": L.gelu_mlp_def(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_def(cfg) -> dict:
    d = _enc_layer_def(cfg)
    d["cross_norm"] = L.layernorm_def(cfg.d_model)
    d["cross"] = L.attention_proj_def(cfg.replace(qk_norm=False))
    return d


def encdec_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_def(cfg.vocab_size, cfg.d_model),
        "enc_layers": stacked(_enc_layer_def(cfg), cfg.encoder_layers),
        "enc_norm": L.layernorm_def(cfg.d_model),
        "dec_layers": stacked(_dec_layer_def(cfg), cfg.num_layers),
        "final_norm": L.layernorm_def(cfg.d_model),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), (EMBED, VOCAB),
                            init="scaled"),
    }


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """enc_embeds: (b, F, d) stub frame embeddings -> encoder states."""
    b, F, _ = enc_embeds.shape
    h = shard(enc_embeds, "batch", "seq", "act_embed")
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (b, F))
    ones = jnp.ones((b, F), jnp.int32)

    def layer_fn(h, lp):
        x = L.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, x, pos)
        k = expand_kv(k, cfg.num_heads)
        v = expand_kv(v, cfg.num_heads)
        attn = segment_attention(q, k, v, ones, ones, causal=False,
                                 chunk=cfg.attn_chunk)
        h = h + L.attn_out_project(lp["attn"], attn)
        x = L.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.gelu_mlp(lp["mlp"], x)
        h = shard(h, "batch", "seq", "act_embed")
        return h, None

    body = jax.checkpoint(layer_fn) if cfg.remat != "none" else layer_fn
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return L.layernorm(params["enc_norm"], h, cfg.norm_eps)


def _cross_block(lp, cfg, h, enc_out, enc_valid):
    x = L.layernorm(lp["cross_norm"], h, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, lp["cross"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
    k = expand_kv(k, cfg.num_heads)
    v = expand_kv(v, cfg.num_heads)
    b, s = x.shape[:2]
    q_seg = jnp.ones((b, s), jnp.int32)
    attn = segment_attention(q, k, v, q_seg, enc_valid, causal=False,
                             chunk=cfg.attn_chunk)
    return h + L.attn_out_project(lp["cross"], attn)


def forward(params, cfg: ModelConfig, batch):
    """Train forward: loss over decoder tokens given stub frame embeds."""
    enc_out = encode(params, cfg, batch["enc_embeds"])
    enc_valid = jnp.ones(enc_out.shape[:2], jnp.int32)
    seg, pos = batch["segment_ids"], batch["positions"]
    h = L.embed(params["embed"], batch["tokens"])
    h = shard(h, "batch", "seq", "act_embed")

    def layer_fn(h, lp):
        x = L.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, x, pos)
        k = expand_kv(k, cfg.num_heads)
        v = expand_kv(v, cfg.num_heads)
        attn = segment_attention(q, k, v, seg, seg, causal=True,
                                 chunk=cfg.attn_chunk)
        h = h + L.attn_out_project(lp["attn"], attn)
        h = _cross_block(lp, cfg, h, enc_out, enc_valid)
        x = L.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.gelu_mlp(lp["mlp"], x)
        h = shard(h, "batch", "seq", "act_embed")
        return h, None

    body = jax.checkpoint(layer_fn) if cfg.remat != "none" else layer_fn
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    h = L.layernorm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["unembed"]
    return shard(logits, "batch", "seq", "act_vocab"), jnp.float32(0.0)


# ---------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim()
    self_shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    cross_shape = (cfg.num_layers, batch, cfg.encoder_frames,
                   cfg.num_kv_heads, hd)
    return {
        "k": jnp.zeros(self_shape, dtype),
        "v": jnp.zeros(self_shape, dtype),
        "cross_k": jnp.zeros(cross_shape, dtype),
        "cross_v": jnp.zeros(cross_shape, dtype),
    }


def cache_logical_axes(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", "act_kv_heads", None)
    cross = ("layers", "batch", None, "act_kv_heads", None)
    return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross}


def build_cross_cache(params, cfg, enc_out):
    """Precompute per-layer cross K/V from encoder states."""
    def one(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    ks, vs = jax.vmap(one)(params["dec_layers"])
    return ks, vs


def prefill(params, cfg: ModelConfig, batch):
    """Prompt pass for the decoder given stub frame embeddings."""
    enc_out = encode(params, cfg, batch["enc_embeds"])
    enc_valid = jnp.ones(enc_out.shape[:2], jnp.int32)
    cross_k, cross_v = build_cross_cache(params, cfg, enc_out)
    seg, pos = batch["segment_ids"], batch["positions"]
    h = L.embed(params["embed"], batch["tokens"])
    h = shard(h, "batch", "seq", "act_embed")

    def layer_fn(h, xs):
        lp, xk, xv = xs
        x = L.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, x, pos)
        ke = expand_kv(k, cfg.num_heads)
        ve = expand_kv(v, cfg.num_heads)
        attn = segment_attention(q, ke, ve, seg, seg, causal=True,
                                 chunk=cfg.attn_chunk)
        h = h + L.attn_out_project(lp["attn"], attn)
        x = L.layernorm(lp["cross_norm"], h, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["cross"]["wq"])
        xke = expand_kv(xk.astype(q.dtype), cfg.num_heads)
        xve = expand_kv(xv.astype(q.dtype), cfg.num_heads)
        q_seg = jnp.ones(x.shape[:2], jnp.int32)
        cattn = segment_attention(q, xke, xve, q_seg, enc_valid,
                                  causal=False, chunk=cfg.attn_chunk)
        h = h + L.attn_out_project(lp["cross"], cattn)
        x = L.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.gelu_mlp(lp["mlp"], x)
        return h, {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

    h, kv = jax.lax.scan(layer_fn, h,
                         (params["dec_layers"], cross_k, cross_v))
    h = L.layernorm(params["final_norm"], h, cfg.norm_eps)
    logits = h[:, -1:, :] @ params["unembed"]
    cache = {"k": kv["k"], "v": kv["v"],
             "cross_k": cross_k, "cross_v": cross_v}
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    b = tokens.shape[0]
    h = L.embed(params["embed"], tokens)
    positions = jnp.full((b, 1), pos, jnp.int32)
    cache_len = jnp.full((b,), pos + 1, jnp.int32)
    f_len = jnp.full((b,), cfg.encoder_frames, jnp.int32)

    def layer_fn(h, xs):
        lp, ck, cv, xk, xv = xs
        x = L.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, x, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 pos, axis=1)
        attn = decode_attention(q, ck, cv, cache_len)
        h = h + L.attn_out_project(lp["attn"], attn)
        # cross attention vs static cross cache
        x = L.layernorm(lp["cross_norm"], h, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, lp["cross"]["wq"])
        cattn = decode_attention(q, xk, xv, f_len)
        h = h + L.attn_out_project(lp["cross"], cattn)
        x = L.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + L.gelu_mlp(lp["mlp"], x)
        return h, {"k": ck, "v": cv}

    h, kv = jax.lax.scan(layer_fn, h, (params["dec_layers"], cache["k"],
                                       cache["v"], cache["cross_k"],
                                       cache["cross_v"]))
    h = L.layernorm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["unembed"]
    new_cache = dict(cache)
    new_cache.update(kv)
    return logits, new_cache
