"""Minimal pure-JAX parameter system.

Models declare a tree of :class:`ParamDef` (shape + logical axis names +
initializer).  From one definition tree we derive:

  * materialized parameters  (``init_params``)
  * logical PartitionSpecs    (``logical_specs``)  -> sharding/logical.py
  * abstract shapes           (``abstract_params``) for the dry-run

Keeping a single source of truth prevents params/spec drift, which is the
usual failure mode of hand-written sharding tables.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis names used across the model zoo.  sharding/logical.py maps
# these onto physical mesh axes ("pod", "data", "model").
EMBED = "embed"          # d_model           -> fsdp (data)
MLP = "mlp"              # d_ff              -> tensor (model)
HEADS = "heads"          # query heads       -> tensor (model)
KV_HEADS = "kv_heads"    # kv heads          -> replicated (GQA small)
HEAD_DIM = "head_dim"    # per-head dim      -> replicated
VOCAB = "vocab"          # vocabulary        -> tensor (model)
EXPERT = "expert"        # MoE experts       -> tensor (model) == EP
LAYERS = "layers"        # scan-stacked dim  -> replicated
SSM_STATE = "ssm_state"  # mamba2 state dim  -> replicated
SSM_INNER = "ssm_inner"  # mamba2 inner dim  -> tensor (model)
RWKV_HEADS = "rwkv_heads"  # wkv heads       -> tensor (model)
LORA = "lora"            # small lora dims   -> replicated
CONV = "conv"            # conv kernel taps  -> replicated
FRAMES = "frames"        # audio frames      -> replicated


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | scaled | uniform
    scale: float | None = None  # stddev override for "normal"/"scaled"
    dtype: Any = None           # override container dtype (e.g. fp32 norms)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch")


def _fan_in(shape: tuple[int, ...]) -> int:
    # Heuristic: all-but-last dims are fan-in for projection matrices.
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1]))


def init_param(defn: ParamDef, key: jax.Array, dtype) -> jax.Array:
    dt = defn.dtype or dtype
    if defn.init == "zeros":
        return jnp.zeros(defn.shape, dt)
    if defn.init == "ones":
        return jnp.ones(defn.shape, dt)
    if defn.init == "uniform":
        lim = defn.scale or 1.0
        return jax.random.uniform(key, defn.shape, dt, -lim, lim)
    if defn.init == "scaled":  # 1/sqrt(fan_in) normal
        std = (defn.scale or 1.0) / math.sqrt(max(_fan_in(defn.shape), 1))
        return (jax.random.normal(key, defn.shape, jnp.float32) * std).astype(dt)
    # default truncated-normal-ish
    std = defn.scale if defn.scale is not None else 0.02
    return (jax.random.normal(key, defn.shape, jnp.float32) * std).astype(dt)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize a ParamDef tree into concrete arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        defs, is_leaf=is_def)


def logical_specs(defs):
    """Tree of logical-axis tuples, mirroring the params tree."""
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def stacked(defs, n: int):
    """Add a leading scan ("layers") dim to every ParamDef in the tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (LAYERS,) + d.axes, d.init,
                           d.scale, d.dtype),
        defs, is_leaf=is_def)


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(defs, dtype=jnp.bfloat16) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(
        int(np.prod(d.shape)) * jnp.dtype(d.dtype or dtype).itemsize
        for d in leaves)
