"""RWKV6 "Finch" — attention-free time mixing with data-dependent decay.

WKV recurrence per head (dk = dv = head_dim):
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   w_t = exp(-exp(w0 + lora_w(x)))

Training uses a chunked formulation where *every* exponent is a cumulative
log-decay difference over a non-empty causal range, hence <= 0: no clamping
tricks or sub-chunk re-scaling are needed (unlike the exp(-cw) factorised
form, which overflows for fast decays).  The intra-chunk term is a fused
(t, s, i) reduce; on TPU this is the Pallas wkv kernel's tile loop
(kernels/wkv6.py), here it is the XLA reference path.

Packing: segment starts inject a -1e30 log-decay *penalty* at the starting
token, which zeroes any state influence crossing the boundary while leaving
within-segment decays untouched.  Padding tokens (seg 0) contribute nothing
to the state (their k is zeroed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import (
    EMBED, LORA, MLP, ParamDef, RWKV_HEADS,
)
from repro.models.layers import layernorm_def
from repro.sharding.logical import shard

_MIX_TARGETS = ("r", "k", "v", "w", "g")
RESET_PENALTY = -1e30


def rwkv6_timemix_def(cfg) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    lo = cfg.rwkv_lora_dim
    p: dict = {
        "ln": layernorm_def(d),
        "mu_base": ParamDef((d,), (None,), init="uniform", scale=0.5),
    }
    for t in _MIX_TARGETS:
        p[f"mu_{t}"] = ParamDef((d,), (None,), init="uniform", scale=0.5)
        p[f"mixA_{t}"] = ParamDef((d, 32), (EMBED, LORA), init="scaled")
        p[f"mixB_{t}"] = ParamDef((32, d), (LORA, EMBED), init="zeros")
    for t in ("r", "k", "v", "g", "o"):
        p[f"w_{t}"] = ParamDef((d, d), (EMBED, None), init="scaled")
    p["w0"] = ParamDef((d,), (None,), init="uniform", scale=1.0)
    p["loraA_w"] = ParamDef((d, lo), (EMBED, LORA), init="scaled")
    p["loraB_w"] = ParamDef((lo, d), (LORA, EMBED), init="zeros")
    p["u"] = ParamDef((h, cfg.rwkv_head_dim), (RWKV_HEADS, None),
                      init="uniform", scale=0.5)
    p["out_ln"] = layernorm_def(cfg.rwkv_head_dim)
    return p


def rwkv6_channelmix_def(cfg) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    return {
        "ln": layernorm_def(d),
        "mu_k": ParamDef((d,), (None,), init="uniform", scale=0.5),
        "mu_r": ParamDef((d,), (None,), init="uniform", scale=0.5),
        "w_k": ParamDef((d, dff), (EMBED, MLP), init="scaled"),
        "w_v": ParamDef((dff, d), (MLP, EMBED), init="scaled"),
        "w_r": ParamDef((d, d), (EMBED, None), init="scaled"),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x: (b, s, d) -> previous-token stream; prev: (b, 1, d) carried state."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p, t: str, x, xs, base_mix):
    mu = p[f"mu_{t}"].astype(x.dtype)
    lora = jnp.tanh(base_mix @ p[f"mixA_{t}"]) @ p[f"mixB_{t}"]
    mix = mu + lora
    return x + (xs - x) * mix


def _per_head_ln(p, x, eps):
    """x: (b, s, h, dk) — GroupNorm(heads) equivalent."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"])


def _project(p, cfg, x, x_shift):
    """Shared r/k/v/w/g/u projection.  Returns fp32 tensors."""
    b, s, d = x.shape
    h = d // cfg.rwkv_head_dim
    dk = cfg.rwkv_head_dim
    base_mix = x + (x_shift - x) * p["mu_base"].astype(x.dtype)
    xr = _ddlerp(p, "r", x, x_shift, base_mix)
    xk = _ddlerp(p, "k", x, x_shift, base_mix)
    xv = _ddlerp(p, "v", x, x_shift, base_mix)
    xw = _ddlerp(p, "w", x, x_shift, base_mix)
    xg = _ddlerp(p, "g", x, x_shift, base_mix)
    r = (xr @ p["w_r"]).reshape(b, s, h, dk).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(b, s, h, dk).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(b, s, h, dk).astype(jnp.float32)
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32))
    w_raw = p["w0"].astype(jnp.float32) \
        + (jnp.tanh(xw @ p["loraA_w"]) @ p["loraB_w"]).astype(jnp.float32)
    loga = -jnp.exp(w_raw).reshape(b, s, h, dk)        # log decay, <= 0
    return r, k, v, g, loga


def wkv6_chunked(r, k, v, loga, u, *, chunk: int, reset: jax.Array,
                 return_state: bool = False):
    """Chunked WKV6.  r,k,v,loga: (b, s, h, dk) fp32; u: (h, dk);
    reset: (b, s) bool — True where a new segment starts (or padding).
    Returns o: (b, s, h, dv) fp32 (+ final state S if requested).
    """
    b, s, h, dk = r.shape
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    # Resets are tracked as COUNTS, never folded into the log-decay sums:
    # adding a -1e30 penalty into an fp32 cumsum destroys every subsequent
    # decay difference (catastrophic cancellation).  A (t, s) interaction
    # is valid iff the running reset count is equal at both ends.
    #
    # Layout is HEAD-MAJOR (b, h, L, dk) throughout the chunk body so the
    # large (b, h, t, s, i) decay tensor is produced and consumed in one
    # layout — the token-major form made XLA materialize a transposed copy
    # of it per chunk step (~17 TB/device/step at rwkv6-3b train_4k scale;
    # EXPERIMENTS.md §Perf iteration R2).
    rst = reset.astype(jnp.int32)

    def split(a):  # (b, s, h, dk) -> (nc, b, h, L, dk)
        return a.reshape(b, nc, L, h, dk).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lac = map(split, (r, k, v, loga))
    pc = rst.reshape(b, nc, L).swapaxes(0, 1)
    tri_strict = jnp.tril(jnp.ones((L, L), bool), k=-1)

    @jax.checkpoint
    def body(S, inp):
        rb, kb, vb, lab, rb_rst = inp               # (b, h, L, dk); (b, L)
        cw = jnp.cumsum(lab, axis=2)                # incl current token
        cwm1 = cw - lab                             # excl current token
        R = jnp.cumsum(rb_rst, axis=1)              # resets up to & incl t
        # state (inter-chunk) term: valid only if NO reset in chunk <= t
        q_valid = (R == 0)[:, None, :, None]
        q_exp = jnp.where(q_valid, jnp.exp(jnp.minimum(cwm1, 0.0)), 0.0)
        o = jnp.einsum("bhti,bhij->bhtj", rb * q_exp, S)
        # intra: A[t,s] = sum_i r[t,i] k[s,i] exp(cwm1_t - cw_s), s < t,
        # valid iff no reset in (s, t]  <=>  R_t == R_s
        expo = cwm1[:, :, :, None] - cw[:, :, None]  # (b, h, t, s, i)
        pair_valid = (R[:, :, None] == R[:, None, :])[:, None, ..., None]
        ex = jnp.where(pair_valid, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        A = jnp.einsum("bhti,bhsi,bhtsi->bhts", rb, kb, ex)
        A = A * tri_strict[None, None]
        o = o + jnp.einsum("bhts,bhsj->bhtj", A, vb)
        # diagonal bonus term: (r_t . (u * k_t)) v_t
        diag = jnp.einsum("bhti,hi,bhti->bht", rb, u, kb)
        o = o + diag[..., None] * vb
        # state update: S' = exp(cw_L) S + sum_s exp(cw_L - cw_s) k_s^T v_s
        # carried state survives only a reset-free chunk; kv_s survives
        # only if no reset in (s, L]
        dec_all = jnp.where((R[:, -1] == 0)[:, None, None],
                            jnp.exp(jnp.minimum(cw[:, :, -1], 0.0)), 0.0)
        k_valid = (R[:, -1:] == R)[:, None, :, None]
        k_hat = kb * jnp.where(
            k_valid, jnp.exp(jnp.minimum(cw[:, :, -1:] - cw, 0.0)), 0.0)
        S_new = S * dec_all[..., None] \
            + jnp.einsum("bhsi,bhsj->bhij", k_hat, vb)
        return S_new, o

    S0 = jnp.zeros((b, h, dk, dk), jnp.float32)
    S_final, os_ = jax.lax.scan(body, S0, (rc, kc, vc, lac, pc))
    # (nc, b, h, L, dk) -> (b, s, h, dk)
    o = os_.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dk)
    if return_state:
        return o, S_final
    return o


def rwkv6_timemix_train(p, cfg, x, segment_ids, return_state: bool = False):
    """x: (b, s, d).  Full time-mix sublayer (includes its own LN)."""
    b, s, d = x.shape
    h = d // cfg.rwkv_head_dim
    from repro.models.layers import layernorm
    xn = layernorm(p["ln"], x, cfg.norm_eps)
    xs = _token_shift(xn, None)
    r, k, v, g, loga = _project(p, cfg, xn, xs)
    prev_seg = jnp.pad(segment_ids[:, :-1], ((0, 0), (1, 0)))
    reset = (segment_ids != prev_seg) | (segment_ids == 0)
    k = k * (segment_ids > 0)[..., None, None]      # padding adds no state
    u = p["u"].astype(jnp.float32)
    o = wkv6_chunked(r, k, v, loga, u, chunk=cfg.rwkv_chunk, reset=reset,
                     return_state=return_state)
    if return_state:
        o, S_final = o
    o = _per_head_ln(p["out_ln"], o, cfg.norm_eps) * g.reshape(b, s, h, -1)
    o = shard(o.astype(x.dtype), "batch", "seq", "act_heads", None)
    out = o.reshape(b, s, d) @ p["w_o"]
    if return_state:
        return out, {"tm_shift": xn[:, -1:], "wkv": S_final}
    return out


def rwkv6_channelmix_train(p, cfg, x):
    from repro.models.layers import layernorm
    xn = layernorm(p["ln"], x, cfg.norm_eps)
    xs = _token_shift(xn, None)
    xk = xn + (xs - xn) * p["mu_k"].astype(xn.dtype)
    xr = xn + (xs - xn) * p["mu_r"].astype(xn.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    kk = shard(kk, "batch", "seq", "act_mlp")
    return jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])


# ---------------------------------------------------------------- decode
def rwkv6_init_state(cfg, batch: int) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dk = cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((batch, 1, d), jnp.bfloat16),
        "cm_shift": jnp.zeros((batch, 1, d), jnp.bfloat16),
        "wkv": jnp.zeros((batch, h, dk, dk), jnp.float32),
    }


def rwkv6_timemix_decode(p, cfg, x, state):
    """x: (b, 1, d).  Returns (out, new_state pieces)."""
    b, _, d = x.shape
    h = d // cfg.rwkv_head_dim
    from repro.models.layers import layernorm
    xn = layernorm(p["ln"], x, cfg.norm_eps)
    xs = state["tm_shift"].astype(xn.dtype)
    r, k, v, g, loga = _project(p, cfg, xn, xs)
    u = p["u"].astype(jnp.float32)
    S = state["wkv"]
    r1, k1, v1 = r[:, 0], k[:, 0], v[:, 0]          # (b, h, dk)
    kv = jnp.einsum("bhi,bhj->bhij", k1, v1)
    o = jnp.einsum("bhi,bhij->bhj", r1, S + u[None, :, :, None] * kv)
    S_new = S * jnp.exp(loga[:, 0])[..., None] + kv
    o = _per_head_ln(p["out_ln"], o[:, None], cfg.norm_eps)[:, 0] \
        * g.reshape(b, 1, h, -1)[:, 0]
    out = (o.reshape(b, 1 * d)[:, None, :]).astype(x.dtype) @ p["w_o"]
    return out, {"tm_shift": xn, "wkv": S_new}


def rwkv6_channelmix_decode(p, cfg, x, state):
    from repro.models.layers import layernorm
    xn = layernorm(p["ln"], x, cfg.norm_eps)
    xs = state["cm_shift"].astype(xn.dtype)
    xk = xn + (xs - xn) * p["mu_k"].astype(xn.dtype)
    xr = xn + (xs - xn) * p["mu_r"].astype(xn.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    return out, {"cm_shift": xn}
