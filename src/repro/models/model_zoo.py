"""Unified model API over all families + ShapeDtypeStruct input specs.

``Model`` bundles the per-family functions behind one interface used by the
trainer, the serving path, and the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, rwkv_model, transformer
from repro.models import params as pdefs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    defs: Any                                   # ParamDef tree
    forward: Callable                           # (params, batch) -> (logits, aux)
    prefill: Callable                           # (params, batch) -> (logits, cache)
    decode_step: Callable                       # (params, cache, tokens, pos)
    init_cache: Callable                        # (batch, max_len) -> cache
    cache_axes: Callable                        # () -> logical axes tree

    def init(self, key: jax.Array, dtype=jnp.bfloat16):
        return pdefs.init_params(self.defs, key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return pdefs.abstract_params(self.defs, dtype)

    def param_count(self) -> int:
        return pdefs.param_count(self.defs)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
        defs = transformer.lm_defs(cfg)
    elif cfg.family == "hybrid":
        mod = hybrid
        defs = hybrid.hybrid_defs(cfg)
    elif cfg.family == "audio":
        mod = encdec
        defs = encdec.encdec_defs(cfg)
    elif cfg.family == "ssm":
        mod = rwkv_model
        defs = rwkv_model.rwkv_defs(cfg)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    if cfg.family == "hybrid":
        forward_fn = hybrid.forward
        prefill_fn = hybrid.prefill
    elif cfg.family == "audio":
        forward_fn = encdec.forward
        prefill_fn = encdec.prefill
    elif cfg.family == "ssm":
        forward_fn = rwkv_model.forward

        def prefill_fn(params, cfg_, batch):  # type: ignore[misc]
            logits, states = rwkv_model.forward(params, cfg_, batch,
                                                return_state=True)
            return logits[:, -1:, :], states
    else:
        forward_fn = transformer.forward
        prefill_fn = transformer.prefill

    return Model(
        cfg=cfg,
        defs=defs,
        forward=lambda p, b: forward_fn(p, cfg, b),
        prefill=lambda p, b: prefill_fn(p, cfg, b),
        decode_step=lambda p, c, t, pos: mod.decode_step(p, cfg, c, t, pos),
        init_cache=lambda b, m, dtype=jnp.bfloat16: mod.init_cache(
            cfg, b, m, dtype),
        cache_axes=lambda: mod.cache_logical_axes(cfg),
    )


# ----------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: a packed token batch (+ modality stubs).
    decode: one new token; the KV cache spec is built separately via
    ``Model.init_cache`` + ``jax.eval_shape``.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": _sds((b, 1), i32)}

    batch = {
        "tokens": _sds((b, s), i32),
        "segment_ids": _sds((b, s), i32),
        "positions": _sds((b, s), i32),
    }
    if shape.kind == "train":
        batch["labels"] = _sds((b, s), i32)
    if cfg.family == "vlm" and cfg.image_token_frac > 0:
        n_img = int(s * cfg.image_token_frac)
        batch["image_embeds"] = _sds((b, n_img, cfg.d_model), jnp.bfloat16)
        batch["image_positions"] = _sds((b, n_img), i32)
    if cfg.family == "audio":
        batch["enc_embeds"] = _sds((b, cfg.encoder_frames, cfg.d_model),
                                   jnp.bfloat16)
    return batch


def batch_logical_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical sharding axes mirroring ``input_specs``."""
    if shape.kind == "decode":
        return {"tokens": ("batch", None)}
    axes = {
        "tokens": ("batch", "seq"),
        "segment_ids": ("batch", "seq"),
        "positions": ("batch", "seq"),
    }
    if shape.kind == "train":
        axes["labels"] = ("batch", "seq")
    if cfg.family == "vlm" and cfg.image_token_frac > 0:
        axes["image_embeds"] = ("batch", "seq", "act_embed")
        axes["image_positions"] = ("batch", "seq")
    if cfg.family == "audio":
        axes["enc_embeds"] = ("batch", "seq", "act_embed")
    return axes
