"""RWKV6 LM assembly: embed -> ln0 -> [timemix + channelmix] x L -> head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import rwkv
from repro.models.params import EMBED, VOCAB, ParamDef, stacked
from repro.sharding.logical import shard


def rwkv_defs(cfg: ModelConfig) -> dict:
    layer = {
        "tm": rwkv.rwkv6_timemix_def(cfg),
        "cm": rwkv.rwkv6_channelmix_def(cfg),
    }
    return {
        "embed": L.embedding_def(cfg.vocab_size, cfg.d_model),
        "ln0": L.layernorm_def(cfg.d_model),
        "layers": stacked(layer, cfg.num_layers),
        "final_norm": L.layernorm_def(cfg.d_model),
        "unembed": ParamDef((cfg.d_model, cfg.vocab_size), (EMBED, VOCAB),
                            init="scaled"),
    }


def forward(params, cfg: ModelConfig, batch, return_state: bool = False):
    seg = batch["segment_ids"]
    h = L.embed(params["embed"], batch["tokens"])
    h = L.layernorm(params["ln0"], h, cfg.norm_eps)
    h = shard(h, "batch", "seq", "act_embed")

    def layer_fn(carry, lp):
        h = carry
        if return_state:
            tm_out, st = rwkv.rwkv6_timemix_train(lp["tm"], cfg, h, seg,
                                                  return_state=True)
            h = h + tm_out
            from repro.models.layers import layernorm
            cm_shift = layernorm(lp["cm"]["ln"], h, cfg.norm_eps)[:, -1:]
            h = h + rwkv.rwkv6_channelmix_train(lp["cm"], cfg, h)
            st["cm_shift"] = cm_shift
            return h, st
        h = h + rwkv.rwkv6_timemix_train(lp["tm"], cfg, h, seg)
        h = h + rwkv.rwkv6_channelmix_train(lp["cm"], cfg, h)
        h = shard(h, "batch", "seq", "act_embed")
        return h, None

    body = layer_fn
    if cfg.remat != "none" and not return_state:
        body = jax.checkpoint(layer_fn)
    h, states = jax.lax.scan(body, h, params["layers"])
    h = L.layernorm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["unembed"]
    logits = shard(logits, "batch", "seq", "act_vocab")
    if return_state:
        return logits, states
    return logits, jnp.float32(0.0)


# ---------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    del max_len  # constant-size state: the point of an SSM
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dk = cfg.rwkv_head_dim
    Lc = cfg.num_layers
    return {
        "tm_shift": jnp.zeros((Lc, batch, 1, d), dtype),
        "cm_shift": jnp.zeros((Lc, batch, 1, d), dtype),
        "wkv": jnp.zeros((Lc, batch, h, dk, dk), jnp.float32),
    }


def cache_logical_axes(cfg: ModelConfig):
    return {"tm_shift": ("layers", "batch", None, None),
            "cm_shift": ("layers", "batch", None, None),
            "wkv": ("layers", "batch", "act_heads", None, None)}


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    del pos  # state carries all positional context
    h = L.embed(params["embed"], tokens)
    h = L.layernorm(params["ln0"], h, cfg.norm_eps)

    def layer_fn(h, xs):
        lp, tm_shift, cm_shift, wkv_state = xs
        tm_out, tm_new = rwkv.rwkv6_timemix_decode(
            lp["tm"], cfg, h, {"tm_shift": tm_shift, "wkv": wkv_state})
        h = h + tm_out
        cm_out, cm_new = rwkv.rwkv6_channelmix_decode(
            lp["cm"], cfg, h, {"cm_shift": cm_shift})
        h = h + cm_out
        return h, {"tm_shift": tm_new["tm_shift"].astype(tm_shift.dtype),
                   "cm_shift": cm_new["cm_shift"].astype(cm_shift.dtype),
                   "wkv": tm_new["wkv"]}

    h, new_cache = jax.lax.scan(
        layer_fn, h,
        (params["layers"], cache["tm_shift"], cache["cm_shift"],
         cache["wkv"]))
    h = L.layernorm(params["final_norm"], h, cfg.norm_eps)
    logits = h @ params["unembed"]
    return logits, new_cache
