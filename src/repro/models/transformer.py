"""Decoder-only transformer LM (dense / MoE / VLM-backbone families).

Layers are scan-stacked (small HLO, fast multi-device compiles) with a
selectable remat policy.  Three entry points per model: ``forward`` (train),
``prefill`` (build KV cache), ``decode_step`` (one token vs full cache).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.attention import (
    decode_attention, expand_kv, segment_attention,
)
from repro.models.params import (
    EMBED, VOCAB, ParamDef, stacked,
)
from repro.sharding.logical import shard


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots_saveable":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)  # "layer": save nothing


# ------------------------------------------------------------------- defs
def layer_def(cfg: ModelConfig) -> dict:
    d = {
        "attn_norm": L.rmsnorm_def(cfg.d_model),
        "attn": L.attention_proj_def(cfg),
        "mlp_norm": L.rmsnorm_def(cfg.d_model),
    }
    if cfg.family == "moe" or cfg.num_experts > 0:
        d["moe"] = moe_lib.moe_def(cfg)
    else:
        d["mlp"] = L.swiglu_def(cfg.d_model, cfg.d_ff)
    return d


def lm_defs(cfg: ModelConfig) -> dict:
    defs = {
        "embed": L.embedding_def(cfg.vocab_size, cfg.d_model),
        "layers": stacked(layer_def(cfg), cfg.num_layers),
        "final_norm": L.rmsnorm_def(cfg.d_model),
    }
    if not cfg.tied_embeddings:
        defs["unembed"] = ParamDef(
            (cfg.d_model, cfg.vocab_size), (EMBED, VOCAB), init="scaled")
    return defs


# ----------------------------------------------------------------- blocks
def _attn_block(lp, cfg, h, segment_ids, positions):
    x = L.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
    q, k, v = L.qkv_project(lp["attn"], cfg, x, positions)
    k = expand_kv(k, cfg.num_heads)
    v = expand_kv(v, cfg.num_heads)
    attn = segment_attention(q, k, v, segment_ids, segment_ids,
                             causal=True, chunk=cfg.attn_chunk)
    attn = shard(attn, "batch", "seq", "act_heads", None)
    return L.attn_out_project(lp["attn"], attn)


def _ffn_block(lp, cfg, h, global_tokens):
    x = L.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
    if "moe" in lp:
        out, aux = moe_lib.moe_block(lp["moe"], cfg, x,
                                     global_tokens=global_tokens)
        return out, aux
    return L.swiglu(lp["mlp"], x), jnp.float32(0.0)


def _embed_inputs(params, cfg, batch):
    h = L.embed(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "image_embeds" in batch:
        b = h.shape[0]
        bi = jnp.arange(b)[:, None]
        h = h.at[bi, batch["image_positions"]].set(
            batch["image_embeds"].astype(h.dtype))
    return shard(h, "batch", "seq", "act_embed")


def _unembed(params, cfg, h):
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tied_embeddings:
        return L.unembed(params["embed"], h)
    logits = h @ params["unembed"]
    return shard(logits, "batch", "seq", "act_vocab")


# ------------------------------------------------------------------ train
def forward(params, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    """batch: tokens/segment_ids/positions (b, s) [+ vlm extras].
    Returns (logits (b, s, vocab), aux_loss scalar)."""
    h = _embed_inputs(params, cfg, batch)
    seg = batch["segment_ids"]
    pos = batch["positions"]
    b, s = seg.shape
    global_tokens = b * s

    def layer_fn(carry, lp):
        h, aux = carry
        h = h + _attn_block(lp, cfg, h, seg, pos)
        ffn, a = _ffn_block(lp, cfg, h, global_tokens)
        h = h + ffn
        h = shard(h, "batch", "seq", "act_embed")
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(
        _remat(layer_fn, cfg), (h, jnp.float32(0.0)), params["layers"])
    return _unembed(params, cfg, h), aux


# ---------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim()
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_logical_axes(cfg: ModelConfig):
    return {"k": ("layers", "batch", "kv_seq", "act_kv_heads", None),
            "v": ("layers", "batch", "kv_seq", "act_kv_heads", None)}


def prefill(params, cfg: ModelConfig, batch):
    """Run the full prompt, return (last-token logits, populated cache)."""
    h = _embed_inputs(params, cfg, batch)
    seg = batch["segment_ids"]
    pos = batch["positions"]
    b, s = seg.shape

    def layer_fn(h, lp):
        x = L.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, x, pos)
        ke = expand_kv(k, cfg.num_heads)
        ve = expand_kv(v, cfg.num_heads)
        attn = segment_attention(q, ke, ve, seg, seg, causal=True,
                                 chunk=cfg.attn_chunk)
        h = h + L.attn_out_project(lp["attn"], attn)
        ffn, _ = _ffn_block(lp, cfg, h, b * s)
        h = h + ffn
        h = shard(h, "batch", "seq", "act_embed")
        return h, {"k": k, "v": v}

    h, kv = jax.lax.scan(_remat(layer_fn, cfg), h, params["layers"])
    logits = _unembed(params, cfg, h[:, -1:, :])
    kv = {n: shard(a, "layers", "batch", "kv_seq", "act_kv_heads", None)
          for n, a in kv.items()}
    return logits, kv


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One decode step.  tokens: (b, 1); pos: scalar int32 — the index the
    new token is written at (cache positions <= pos are attended).
    Returns (logits (b, 1, vocab), updated cache)."""
    b = tokens.shape[0]
    h = L.embed(params["embed"], tokens)
    h = shard(h, "batch", "seq", "act_embed")
    positions = jnp.full((b, 1), pos, jnp.int32)
    cache_len = jnp.full((b,), pos + 1, jnp.int32)

    def layer_fn(h, xs):
        lp, ck, cv = xs
        x = L.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], cfg, x, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 pos, axis=1)
        attn = decode_attention(q, ck, cv, cache_len)
        h = h + L.attn_out_project(lp["attn"], attn)
        ffn, _ = _ffn_block(lp, cfg, h, b)
        h = h + ffn
        return h, {"k": ck, "v": cv}

    h, new_cache = jax.lax.scan(
        layer_fn, h, (params["layers"], cache["k"], cache["v"]))
    return _unembed(params, cfg, h), new_cache
