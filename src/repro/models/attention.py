"""Attention: segment-aware (packed) online-softmax attention.

Three entry points:
  * ``segment_attention``         — chunked online-softmax (flash-style) over
                                    KV blocks; the training/prefill path, and
                                    the lowering reference for the Pallas
                                    kernel in kernels/packed_attention.py.
  * ``full_segment_attention``    — unchunked oracle (tests / tiny configs).
  * ``decode_attention``          — one-token step against a (possibly
                                    sequence-sharded) KV cache.

Packing semantics: segment id 0 marks padding; q attends to k iff
``seg_q == seg_k != 0`` and (causal) buffer index ``k <= q``.  This is
exactly the workload the OVERLORD planner balances: per-microbatch FLOPs
are proportional to sum(l_i^2) over packed segments.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def expand_kv(x: jax.Array, num_heads: int) -> jax.Array:
    """(b, s, kh, d) -> (b, s, h, d) by repeating each kv head h/kh times.

    The jnp path expands GQA KV heads explicitly (XLA fuses the broadcast
    into the dot); the Pallas kernel path handles GQA without expansion.
    """
    kh = x.shape[2]
    if kh == num_heads:
        return x
    assert num_heads % kh == 0, (num_heads, kh)
    return jnp.repeat(x, num_heads // kh, axis=2)


def _mask(q_seg, k_seg, q_idx, k_idx, causal):
    m = (q_seg[:, None, :, None] == k_seg[:, None, None, :]) \
        & (k_seg[:, None, None, :] > 0)
    if causal:
        m = m & (q_idx[None, None, :, None] >= k_idx[None, None, None, :])
    return m


def full_segment_attention(q, k, v, q_seg, kv_seg, *, causal: bool = True,
                           q_offset: int = 0):
    """Unchunked oracle.  q: (b,sq,h,d); k,v: (b,sk,h,d)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_idx = jnp.arange(sq) + q_offset
    k_idx = jnp.arange(sk)
    mask = _mask(q_seg, kv_seg, q_idx, k_idx, causal)
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p / jnp.maximum(l, 1e-20), v)
    valid = (q_seg > 0)[:, :, None, None]
    return jnp.where(valid, out, 0.0).astype(q.dtype)


def segment_attention(q, k, v, q_seg, kv_seg, *, causal: bool = True,
                      chunk: int = 1024, q_offset: int = 0):
    """Chunked online-softmax attention over KV blocks.

    Flash-attention memory profile on the jnp path: per-step logits are
    (b, h, sq, chunk); the scan body is rematerialized in the backward pass.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    chunk = min(chunk, sk)
    if sk % chunk != 0:
        pad = (-sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)))  # seg 0 == masked
        sk += pad
    n_chunks = sk // chunk
    if n_chunks == 1:
        return full_segment_attention(q, k, v, q_seg, kv_seg, causal=causal,
                                      q_offset=q_offset)

    scale = d ** -0.5
    q_idx = jnp.arange(sq) + q_offset

    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    segc = kv_seg.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    starts = jnp.arange(n_chunks) * chunk

    init = (
        jnp.full((b, h, sq), NEG_INF, jnp.float32),       # running max
        jnp.zeros((b, h, sq), jnp.float32),               # running denom
        jnp.zeros((b, h, sq, d), jnp.float32),            # running numer
    )

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        k_blk, v_blk, seg_blk, k0 = inp
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_blk,
                            preferred_element_type=jnp.float32) * scale
        k_idx = k0 + jnp.arange(chunk)
        mask = _mask(q_seg, seg_blk, q_idx, k_idx, causal)
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, -1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l = l * corr + jnp.sum(p, -1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, segc, starts))
    out = acc / jnp.maximum(l, 1e-20)[..., None]          # (b,h,sq,d)
    out = out.transpose(0, 2, 1, 3)                       # (b,sq,h,d)
    valid = (q_seg > 0)[:, :, None, None]
    return jnp.where(valid, out, 0.0).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len):
    """One-step decode.  q: (b,1,h,d); caches: (b,S,kh,d) (S may be sharded
    over the tensor axis — the stable-softmax reductions then lower to
    small all-reduces, i.e. KV-sequence-parallel flash-decode).
    cache_len: (b,) number of valid cache positions per sequence.
    """
    b, _, h, d = q.shape
    S = k_cache.shape[1]
    k = expand_kv(k_cache, h)
    v = expand_kv(v_cache, h)
    scale = d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(S)[None, :] < cache_len[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p / jnp.maximum(l, 1e-20), v)
    return out.astype(q.dtype)
