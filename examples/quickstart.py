"""Quickstart: stand up the OVERLORD data plane, fetch balanced batches,
inspect the orchestration diagnostics.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group


def main():
    # 1. multisource data: 4 skewed image-text sources on disk
    root = tempfile.mkdtemp(prefix="overlord_quickstart_")
    specs = coyo_like_specs(4)
    paths = materialize_group(specs, root)

    # 2. trainer topology: PP=1, DP=4, CP=1, TP=2 (8 clients)
    tree = ClientPlaceTree([("PP", 1), ("DP", 4), ("CP", 1), ("TP", 2)])

    # 3. declarative plan: mix sources evenly, balance the quadratic
    #    attention cost of packed sequences across DP buckets
    cfg = get_config("qwen3-8b")
    ov = Overlord(
        paths, tree,
        StaticSchedule({s.name: 1.0 for s in specs}),
        OverlordConfig(
            seq_len=512, rows_per_microbatch=2, n_bins=2,
            strategy="backbone_balance",
            strategy_params=dict(costfn=backbone_cost(cfg),
                                 broadcast=("TP",)),
        )).start()
    try:
        for step in range(3):
            for rank in range(tree.world):
                view = ov.get_batch(step, rank)
                if step == 0:
                    what = view["bins"][0].tokens.shape \
                        if view["role"] == "data" else view["role"]
                    print(f"rank {rank}: {view['role']:9s} {what}")
            ov.step_done(step)
        for d in ov.diagnostics():
            bal = d["balance:main"]
            print(f"step {d['step']}: imbalance={bal['imbalance']:.3f} "
                  f"(method={bal['method']})")
        print("memory:", {k: f"{v / 1e6:.2f}MB"
                          for k, v in ov.memory_report().items()})
    finally:
        ov.shutdown()


if __name__ == "__main__":
    main()
