"""End-to-end driver: train a reduced LM for a few hundred steps with the
OVERLORD data plane feeding balanced packed batches.

    PYTHONPATH=src python examples/train_e2e.py --steps 200

Scale note: on this CPU container the model is the reduced config (~10M
params at --width 256).  On a pod, drop --reduced-width to train the full
assigned config through the identical code path (launch/train.py).
"""
import argparse
import tempfile

from repro.configs.qwen3_8b import CONFIG
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group
from repro.models.model_zoo import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    cfg = CONFIG.replace(
        name="qwen3-e2e", num_layers=args.layers, d_model=args.width,
        num_heads=4, num_kv_heads=2, head_dim=max(args.width // 4, 16),
        d_ff=args.width * 3, vocab_size=4096, attn_chunk=128)
    model = build_model(cfg)
    print(f"model params: {model.param_count():,}")

    root = tempfile.mkdtemp(prefix="overlord_e2e_")
    specs = coyo_like_specs(4)
    paths = materialize_group(specs, root)
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    ov = Overlord(paths, tree,
                  StaticSchedule({s.name: 1.0 for s in specs}),
                  OverlordConfig(
                      seq_len=args.seq_len, rows_per_microbatch=2,
                      n_bins=1, strategy="backbone_balance",
                      strategy_params=dict(costfn=backbone_cost(cfg),
                                           broadcast=()),
                      vocab_size=cfg.vocab_size)).start()
    try:
        trainer = Trainer(model, ov, TrainerConfig(
            steps=args.steps, log_every=20,
            opt=AdamWConfig(peak_lr=3e-3, warmup_steps=20,
                            total_steps=args.steps)))
        hist = trainer.train()
        first = sum(h["loss"] for h in hist[:10]) / 10
        last = sum(h["loss"] for h in hist[-10:]) / 10
        print(f"mean loss first10={first:.4f} last10={last:.4f}")
        assert last < first, "loss did not improve"
        print("OK: loss improved with OVERLORD-fed batches")
    finally:
        ov.shutdown()


if __name__ == "__main__":
    main()
