"""Non-interrupted fault tolerance demo (paper §6.1 / Fig. 16).

Drives a SEEDED chaos schedule (docs/FAULT_TOLERANCE.md) against a live
cluster: loader/planner crashes (shadow promotion + differential-
checkpoint recovery), storage io-errors (retry policy + circuit
breaker), corrupted samples (dead-letter queue), hangs and slowdowns —
while the delivery ledger proves no sample was lost or duplicated.

    PYTHONPATH=src python examples/fault_tolerance_demo.py [seed]
"""
import sys
import tempfile
import time

from repro.chaos import FaultInjector, FaultSchedule
from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group

STEPS = 40


def main(seed: int = 1234):
    root = tempfile.mkdtemp(prefix="overlord_chaos_")
    specs = coyo_like_specs(3)
    paths = materialize_group(specs, root)
    cfg = get_config("qwen3-8b")
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    ov = Overlord(paths, tree,
                  StaticSchedule({s.name: 1.0 for s in specs}),
                  OverlordConfig(
                      seq_len=256, rows_per_microbatch=2, n_bins=1,
                      strategy="backbone_balance",
                      strategy_params=dict(costfn=backbone_cost(cfg),
                                           broadcast=()),
                      prefetch=3, shadows=True, ledger=True)).start()

    schedule = FaultSchedule.generate(seed, STEPS)
    print(f"chaos schedule (seed={seed}, {len(schedule)} events):")
    for ev in schedule.events:
        print(f"  step {ev.step:3d}  {ev.kind:13s} target={ev.target} "
              f"{ev.param_dict()}")
    injector = FaultInjector(ov, schedule)

    try:
        fault_steps = {ev.step for ev in schedule.events}
        for step in range(STEPS):
            fired = injector.on_step(step)
            t0 = time.time()
            for rank in range(tree.world):
                ov.get_batch(step, rank, timeout=30)
            stall = time.time() - t0
            marker = "".join(f"  !! {kind} -> {target}"
                             for (_, kind, target, _) in fired)
            print(f"step {step:3d} fetch {stall * 1e3:7.2f}ms{marker}")
            ov.step_done(step)
            assert step not in fault_steps or fired

        time.sleep(0.3)               # let in-flight recoveries settle
        ov.step_done(STEPS - 1)       # refresh the quarantine mirror

        print(f"\nshadow promotions: "
              f"{[p['name'] for p in ov.shadow_mgr.promotions]}")
        print(f"recoveries: "
              f"{[(r['actor'], round(r['recovery_s'], 4)) for r in ov.recovery_log]}")
        dlq = ov.dlq.counts_by_source()
        print(f"dead-letter queue: {sum(dlq.values())} quarantined "
              f"{dlq}")
        report = ov.resilience_report()
        print(f"checkpoint save failures: "
              f"{report['checkpoints']['save_failures']}")
        print(f"shadow sync failures: "
              f"{report['shadows']['sync_failures']}")
        summary = ov.ledger.verify(strict=True)
        print(f"\nledger: planned={summary['planned']} "
              f"delivered={summary['delivered']} "
              f"dropped={summary['dropped']} "
              f"quarantined={summary['quarantined']}")
        print("verified: zero lost, zero duplicated — "
              "delivery was never interrupted.")
    finally:
        injector.uninstall()
        ov.shutdown()


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 1234)
