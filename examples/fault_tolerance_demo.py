"""Non-interrupted fault tolerance demo (paper §6.1 / Fig. 16).

Kills loaders (shadow promotion) and the planner (differential-checkpoint
recovery) mid-run; training-side delivery never pauses.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import tempfile
import time

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, Overlord, OverlordConfig, StaticSchedule,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group


def main():
    root = tempfile.mkdtemp(prefix="overlord_ft_")
    specs = coyo_like_specs(3)
    paths = materialize_group(specs, root)
    cfg = get_config("qwen3-8b")
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    ov = Overlord(paths, tree,
                  StaticSchedule({s.name: 1.0 for s in specs}),
                  OverlordConfig(
                      seq_len=256, rows_per_microbatch=2, n_bins=1,
                      strategy="backbone_balance",
                      strategy_params=dict(costfn=backbone_cost(cfg),
                                           broadcast=()),
                      prefetch=3, shadows=True)).start()
    try:
        for step in range(30):
            if step == 10:
                names = ov.inject_loader_failures(2)
                print(f"  !! killed loaders at step {step}: {names}")
            if step == 20:
                ov.inject_planner_failure()
                print(f"  !! killed planner at step {step}")
            t0 = time.time()
            for rank in range(tree.world):
                ov.get_batch(step, rank, timeout=20)
            stall = time.time() - t0
            marker = " <-- failure window" if step in (10, 20) else ""
            print(f"step {step:3d} fetch {stall * 1e3:7.2f}ms{marker}")
            ov.step_done(step)
        print(f"\nshadow promotions: "
              f"{[p['name'] for p in ov.shadow_mgr.promotions]}")
        print(f"recoveries: "
              f"{[(r['actor'], round(r['recovery_s'], 4)) for r in ov.recovery_log]}")
        print("delivery was never interrupted.")
    finally:
        ov.shutdown()


if __name__ == "__main__":
    main()
