"""Curriculum learning + mixture-driven autoscaling demo (paper §4/§5.2).

The schedule ramps from an 'easy' text source to 'hard' multimodal
sources; the Planner's moving-average trigger scales the hard sources'
loader shards up as their weight crosses the threshold.

    PYTHONPATH=src python examples/curriculum.py
"""
import tempfile

from repro.configs import get_config
from repro.core import (
    ClientPlaceTree, CurriculumSchedule, Overlord, OverlordConfig,
)
from repro.data.cost_models import backbone_cost
from repro.data.sources import coyo_like_specs, materialize_group


def main():
    root = tempfile.mkdtemp(prefix="overlord_curriculum_")
    specs = coyo_like_specs(4)
    paths = materialize_group(specs, root)
    names = [s.name for s in specs]
    sched = CurriculumSchedule(
        easy={names[0]: 1.0},
        hard={names[2]: 0.5, names[3]: 0.5},
        ramp_steps=20)
    cfg = get_config("qwen3-8b")
    tree = ClientPlaceTree([("PP", 1), ("DP", 2), ("CP", 1), ("TP", 1)])
    ov = Overlord(paths, tree, sched, OverlordConfig(
        seq_len=256, rows_per_microbatch=2, n_bins=1,
        strategy="backbone_balance",
        strategy_params=dict(costfn=backbone_cost(cfg), broadcast=()),
    )).start()
    try:
        for step in range(30):
            for rank in range(tree.world):
                ov.get_batch(step, rank)
            ov.step_done(step)
            if step % 5 == 0:
                w = sched.weights(step)
                top = sorted(w.items(), key=lambda kv: -kv[1])[:2]
                print(f"step {step:3d} weights: "
                      + ", ".join(f"{k}={v:.2f}" for k, v in top))
        events = ov.planner.call("scale_events")
        print(f"\nmixture-driven scale events: {len(events)}")
        for e in events:
            print(f"  step {e['step']}: {e['source']} -> {e['dir']} "
                  f"(ema={e['ema']:.2f})")
        shards = {s: ov.scaler.current_shards(s) for s in names}
        print("loader shards now:", shards)
    finally:
        ov.shutdown()


if __name__ == "__main__":
    main()
